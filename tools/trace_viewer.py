"""Convert a telemetry JSONL event stream into a Chrome/Perfetto trace.

The serving engine can stream raw lifecycle events as JSONL while it runs
(``--events-out`` on ``repro.launch.serve``, or ``Telemetry(jsonl_path=...)``
directly).  This tool turns that stream into the Chrome trace-event format
accepted by https://ui.perfetto.dev and ``chrome://tracing`` — one timeline
lane per KV slot, a scheduler lane for queue events, and counter tracks for
the engine gauges.

    PYTHONPATH=src python tools/trace_viewer.py events.jsonl run.trace.json
    PYTHONPATH=src python tools/trace_viewer.py events.jsonl   # -> stdout

(``serve.py --trace-out`` and ``serving_bench.py --trace-out`` write the
trace directly; this tool exists for streams captured as JSONL, e.g. from a
long run you want to inspect before it finishes.)
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.serving.telemetry import load_events_jsonl  # noqa: E402
from repro.serving.trace import chrome_trace  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("events", help="telemetry JSONL (one event per line)")
    ap.add_argument("out", nargs="?", default=None,
                    help="output .trace.json (default: stdout)")
    ap.add_argument("--name", default="serving-engine",
                    help="process name shown in the Perfetto UI")
    args = ap.parse_args(argv)

    events = load_events_jsonl(args.events)
    if not events:
        print(f"[trace_viewer] no events in {args.events}", file=sys.stderr)
        return 1
    doc = chrome_trace(events, engine_name=args.name)
    text = json.dumps(doc)
    if args.out:
        Path(args.out).write_text(text)
        print(f"[trace_viewer] {len(events)} events -> {args.out} "
              f"({len(doc['traceEvents'])} trace entries); open at "
              "https://ui.perfetto.dev")
    else:
        print(text)
    return 0


if __name__ == "__main__":
    sys.exit(main())
