"""Benchmark harness entry point: one function per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--skip-slow] [--json out.json]

Emits ``name,metric,value`` CSV lines plus a JSON dump.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path


def _flatten(prefix: str, obj, rows: list):
    if isinstance(obj, dict):
        for k, v in obj.items():
            _flatten(f"{prefix}.{k}" if prefix else str(k), v, rows)
    elif isinstance(obj, (int, float, bool)):
        rows.append((prefix, obj))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-slow", action="store_true",
                    help="skip table1 (trains a small model)")
    ap.add_argument("--json", default="reports/bench.json")
    ap.add_argument("--reports", default="reports/dryrun",
                    help="dry-run report dir for the roofline table")
    args = ap.parse_args(argv)

    from benchmarks import paper_figures as pf

    benches = [
        ("fig7a_context_sweep", pf.fig7a_context_sweep),
        ("fig7b_speedup", pf.fig7b_speedup),
        ("lut_exp_error", pf.lut_exp_error),
        ("fxp_attention_precision", pf.fxp_attention_precision),
        ("fig8a_breakdown", pf.fig8a_breakdown),
        ("table3_tokens_per_s", pf.table3_tokens_per_s),
    ]
    if not args.skip_slow:
        benches.append(("table1_topk_agreement", pf.table1_topk_agreement))

    results, failures = {}, []
    for name, fn in benches:
        t0 = time.perf_counter()
        try:
            results[name] = fn()
            results[name]["_wall_s"] = round(time.perf_counter() - t0, 2)
            status = "ok"
        except Exception as e:  # pragma: no cover
            failures.append(name)
            results[name] = {"error": f"{type(e).__name__}: {e}"}
            status = "FAIL"
        print(f"[{status}] {name} ({results[name].get('_wall_s', '-')}s)")

    # roofline table from the dry-run sweep, if reports exist
    try:
        from benchmarks import roofline_table
        md = roofline_table.markdown(args.reports)
        results["roofline_table"] = {"markdown": md}
        print("\n=== Roofline (single-pod baselines) ===")
        print(md)
    except Exception as e:
        print(f"[skip] roofline table: {e}")

    print("\n=== CSV ===")
    print("name,metric,value")
    for name, res in results.items():
        rows: list = []
        _flatten("", res, rows)
        for metric, value in rows:
            if metric.startswith("_") or metric == "markdown":
                continue
            print(f"{name},{metric},{value}")

    out = Path(args.json)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(results, indent=1, default=str))
    print(f"\nwrote {out}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
