"""CI perf-regression gate: compare a fresh BENCH JSON against a checked-in
baseline with per-metric tolerances.

The serving bench legs already gate an absolute floor (``--check``:
speedup >= 1.3x) and correctness (``--verify``); what they could not catch
is a *relative* regression — PR N+1 quietly dropping PR 3's 2.26x to 1.5x,
or dispatches/token creeping up, while still clearing the floor. This
script closes that hole: every perf-smoke leg runs it against
``benchmarks/baselines/<same filename>`` after the bench.

Checks, per arch entry:

* ``speedup_tokens_per_s`` — the machine-normalized throughput ratio
  (continuous / lock-step on the same host, so CI hardware variance mostly
  cancels): fresh must be >= baseline * (1 - 25%);
* ``dispatches_per_token`` — deterministic for a backlogged trace: fresh
  must be <= baseline * (1 + 2%);
* ``generated_tokens`` — exact: the trace and greedy outputs are seeded,
  so any drift means the workload or the model changed under the bench;
* ``verify`` — ``verify_mismatched_rids`` must be empty whenever present;
* ``telemetry overhead`` — when the fresh entry carries a telemetry
  section (``--trace-out`` runs), enabled-vs-disabled throughput must be
  within 3% and tokens identical;
* ``quant`` entries (``--verify-agreement`` runs on ``+w4a8`` archs):
  ``agreement_rate`` gated both absolutely (>= the entry's own
  ``agreement_target``, the 0.90 floor) and relatively (>= baseline - 2%,
  so a quantization change that quietly costs agreement is a regression
  even while clearing the floor); ``kv_bytes_per_slot`` and the fp32-twin
  ``kv_bytes_ratio`` pinned exactly — the byte footprint is a function of
  shapes and dtypes, any drift means the cache format changed;
* ``chaos`` entries (``bench: "serving_chaos"`` from ``--faults`` runs)
  swap the perf tolerances for the recovery contract: the deterministic
  counters (errored / shed / generated tokens / faults fired / dispatch
  retries) pinned exactly against the baseline, the contract booleans
  (victim-only quarantine, unaffected-stream identity, victim prefix,
  replay determinism, post-run audit) true, and zero slot/source leaks.

Schema guard: entries are stamped (``schema_version``, config, seed, jax
version, git describe — see ``serving_bench.py``); a fresh/baseline
``schema_version`` mismatch, or differing trace parameters (arch, seed,
slots, lengths, ticks), is a **refusal** (exit 2, the numbers are not
comparable), distinct from a regression (exit 1).

Output: a readable per-metric diff table plus a machine-readable JSON
verdict on the last stdout line (and to ``--json`` when given).

    PYTHONPATH=src python benchmarks/check_regression.py BENCH_serving.json
    PYTHONPATH=src python benchmarks/check_regression.py \
        BENCH_serving_ring.json --baseline-dir benchmarks/baselines
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

SCHEMA_VERSION = 2          # bump when BENCH entry semantics change

# metric -> (kind, tolerance). "min_rel": fresh >= base*(1-tol) (higher is
# better); "max_rel": fresh <= base*(1+tol) (lower is better); "exact".
TOLERANCES = {
    "speedup_tokens_per_s": ("min_rel", 0.25),
    "dispatches_per_token": ("max_rel", 0.02),
    "generated_tokens": ("exact", 0),
}
TELEMETRY_OVERHEAD_MAX_PCT = 3.0
# quant (+w4a8) entries: agreement may wobble a little across BLAS builds
# (a flipped token flips every token after it), so the relative gate
# allows 2%; the absolute floor (the entry's own agreement_target) always
# applies. Byte metrics are shape-determined and pinned exactly.
QUANT_AGREEMENT_REL_TOL = 0.02

# trace parameters that must be identical for the numbers to be comparable
# (keys absent from both entries — e.g. the chaos / trace-shape knobs on
# baselines that predate them — compare equal, so old baselines stay valid)
IDENTITY_KEYS = ("bench", "arch", "reduced", "n_slots", "n_requests",
                 "max_len", "chunk", "decode_ticks", "prompt_len", "max_new",
                 "trace_shape", "rate", "fault_seed", "n_faults")

# chaos entries (bench == "serving_chaos"): deterministic recovery counters
# pinned exactly against the baseline, plus contract booleans that must be
# true on the fresh run regardless of what the baseline recorded
CHAOS_EXACT = ("n_errored", "n_shed", "generated_tokens", "faults_fired",
               "dispatch_retries")
CHAOS_FLAGS = ("victim_only_quarantine", "unaffected_identical",
               "victim_prefix_ok", "replay_identical", "audit_clean")
CHAOS_ZERO = ("slot_leaks", "src_leaks")


class SchemaMismatch(Exception):
    """Fresh and baseline are not comparable (refusal, not a regression)."""


def _entries(doc) -> list[dict]:
    return doc if isinstance(doc, list) else [doc]


def _deep_get(entry: dict, dotted: str):
    cur = entry
    for part in dotted.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
    return cur


def _require_comparable(fresh: dict, base: dict) -> None:
    fv, bv = fresh.get("schema_version"), base.get("schema_version")
    if fv != bv:
        raise SchemaMismatch(
            f"schema_version mismatch: fresh={fv} baseline={bv} — "
            "regenerate the baseline (benchmarks/baselines/) instead of "
            "comparing across schemas")
    if fv != SCHEMA_VERSION:
        raise SchemaMismatch(
            f"schema_version {fv} unsupported by this checker "
            f"(expects {SCHEMA_VERSION})")
    fresh_seed = _deep_get(fresh, "meta.seed")
    base_seed = _deep_get(base, "meta.seed")
    if fresh_seed != base_seed:
        raise SchemaMismatch(
            f"trace seed differs (fresh={fresh_seed} baseline={base_seed})")
    for key in IDENTITY_KEYS:
        if fresh.get(key) != base.get(key):
            raise SchemaMismatch(
                f"{fresh.get('arch')}: bench parameter {key!r} differs "
                f"(fresh={fresh.get(key)!r} baseline={base.get(key)!r}) — "
                "the traces are not the same workload")


def compare_entry(fresh: dict, base: dict) -> list[dict]:
    """Per-metric checks for one arch entry; raises SchemaMismatch when the
    two entries are not comparable at all."""
    _require_comparable(fresh, base)
    checks = []

    def add(metric, f, b, limit, ok, note=""):
        checks.append({"arch": fresh.get("arch"), "metric": metric,
                       "fresh": f, "baseline": b, "limit": limit,
                       "ok": bool(ok), "note": note})

    if fresh.get("bench") == "serving_chaos":
        fc, bc = fresh.get("chaos") or {}, base.get("chaos") or {}
        for metric in CHAOS_EXACT:
            f, b = fc.get(metric), bc.get(metric)
            add(metric, f, b, f"== {b}", f is not None and f == b, "exact")
        for metric in CHAOS_FLAGS:
            add(metric, fc.get(metric), True, "== True",
                fc.get(metric) is True, "recovery contract")
        for metric in CHAOS_ZERO:
            add(metric, fc.get(metric), 0, "== 0", fc.get(metric) == 0, "")
        add("audit_checks", fc.get("audit_checks"), None, "> 0",
            bool(fc.get("audit_checks")), "auditor actually ran")
        bad = fresh.get("verify_mismatched_rids")
        if bad is not None:
            add("verify_mismatched", len(bad), 0, "== 0", len(bad) == 0,
                str(bad) if bad else "")
        return checks

    for metric, (kind, tol) in TOLERANCES.items():
        f = fresh.get(metric, _deep_get(fresh, f"continuous.{metric}"))
        b = base.get(metric, _deep_get(base, f"continuous.{metric}"))
        if f is None or b is None:
            add(metric, f, b, None, False, "metric missing")
            continue
        if kind == "min_rel":
            limit = round(b * (1 - tol), 4)
            add(metric, f, b, f">= {limit}", f >= limit,
                f"-{tol:.0%} of baseline")
        elif kind == "max_rel":
            limit = round(b * (1 + tol), 4)
            add(metric, f, b, f"<= {limit}", f <= limit,
                f"+{tol:.0%} of baseline")
        else:
            add(metric, f, b, f"== {b}", f == b, "exact")

    bad = fresh.get("verify_mismatched_rids")
    if bad is not None:
        add("verify_mismatched", len(bad), 0, "== 0", len(bad) == 0,
            str(bad) if bad else "")

    fq, bq = fresh.get("quant"), base.get("quant")
    if fq is not None or bq is not None:
        if fq is None or bq is None:
            add("quant_section", fq is not None, bq is not None,
                "present in both", False,
                "quant section missing on one side — rerun with "
                "--verify-agreement or regenerate the baseline")
        else:
            f = fq.get("agreement_rate")
            floor = fq.get("agreement_target")
            add("quant.agreement_floor", f, floor, f">= {floor}",
                f is not None and floor is not None and f >= floor,
                "absolute floor")
            b = bq.get("agreement_rate")
            if b is not None:
                limit = round(b * (1 - QUANT_AGREEMENT_REL_TOL), 4)
                add("quant.agreement_rate", f, b, f">= {limit}",
                    f is not None and f >= limit,
                    f"-{QUANT_AGREEMENT_REL_TOL:.0%} of baseline")
            fb = _deep_get(fresh, "continuous.kv_bytes_per_slot")
            bb = _deep_get(base, "continuous.kv_bytes_per_slot")
            add("kv_bytes_per_slot", fb, bb, f"== {bb}",
                fb is not None and fb == bb, "exact (cache format)")
            fr, br = fq.get("kv_bytes_ratio"), bq.get("kv_bytes_ratio")
            add("quant.kv_bytes_ratio", fr, br, f"== {br}",
                fr is not None and fr == br, "exact (fp32-twin ratio)")

    tel = fresh.get("telemetry")
    if tel is not None:
        add("telemetry_overhead_pct", tel.get("overhead_pct"), None,
            f"<= {TELEMETRY_OVERHEAD_MAX_PCT}",
            (tel.get("overhead_pct") is not None
             and tel["overhead_pct"] <= TELEMETRY_OVERHEAD_MAX_PCT),
            "enabled vs disabled throughput")
        add("telemetry_tokens_identical", tel.get("tokens_identical"), True,
            "== True", tel.get("tokens_identical") is True, "")
    return checks


def compare(fresh_doc, base_doc) -> list[dict]:
    base_by_arch = {e.get("arch"): e for e in _entries(base_doc)}
    checks = []
    for entry in _entries(fresh_doc):
        arch = entry.get("arch")
        if arch not in base_by_arch:
            raise SchemaMismatch(
                f"no baseline entry for arch {arch!r} "
                f"(baseline has {sorted(base_by_arch)})")
        checks.extend(compare_entry(entry, base_by_arch[arch]))
    return checks


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("fresh", help="freshly generated BENCH JSON")
    ap.add_argument("--baseline", default=None,
                    help="baseline JSON (default: --baseline-dir/<name>)")
    ap.add_argument("--baseline-dir",
                    default=str(Path(__file__).parent / "baselines"))
    ap.add_argument("--json", default=None,
                    help="also write the machine-readable verdict here")
    args = ap.parse_args(argv)

    fresh_path = Path(args.fresh)
    base_path = Path(args.baseline) if args.baseline else (
        Path(args.baseline_dir) / fresh_path.name)
    verdict = {"fresh": str(fresh_path), "baseline": str(base_path),
               "pass": False, "refused": None, "checks": []}

    try:
        if not base_path.exists():
            raise SchemaMismatch(f"baseline {base_path} does not exist")
        checks = compare(json.loads(fresh_path.read_text()),
                         json.loads(base_path.read_text()))
    except SchemaMismatch as e:
        verdict["refused"] = str(e)
        print(f"[check_regression] REFUSED: {e}", file=sys.stderr)
        print(json.dumps(verdict))
        if args.json:
            Path(args.json).write_text(json.dumps(verdict, indent=1))
        return 2

    verdict["checks"] = checks
    verdict["pass"] = all(c["ok"] for c in checks)
    print(f"[check_regression] {fresh_path.name} vs {base_path}")
    arch = None
    for c in checks:
        if c["arch"] != arch:
            arch = c["arch"]
            print(f"  {arch}:")
        mark = "OK  " if c["ok"] else "FAIL"
        note = f"  ({c['note']})" if c["note"] else ""
        print(f"    [{mark}] {c['metric']:<26} {c['fresh']!r:>10} "
              f"vs baseline {c['baseline']!r} (want {c['limit']}){note}")
    n_bad = sum(not c["ok"] for c in checks)
    print(f"  {'PASS' if verdict['pass'] else 'FAIL'}: "
          f"{len(checks) - n_bad}/{len(checks)} checks passed")
    print(json.dumps(verdict))
    if args.json:
        Path(args.json).write_text(json.dumps(verdict, indent=1))
    return 0 if verdict["pass"] else 1


if __name__ == "__main__":
    sys.exit(main())
