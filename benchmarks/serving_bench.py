"""Serving benchmark: continuous batching vs the lock-step baseline.

Replays the same ragged Poisson trace (mixed prompt / output lengths)
through both engines and compares useful-token throughput:

* **lock-step** — FIFO groups of ``n_slots`` requests through
  ``ServingEngine``: prompts right-padded to a uniform length, every group
  decodes for its longest member's budget (the padding + convoy waste this
  subsystem exists to remove);
* **continuous** — ``ContinuousBatchingEngine``: chunked slot prefill,
  per-slot retirement, immediate backfill, and multi-tick decode blocks
  (``--decode-ticks K``: K fused ticks per dispatch with on-device
  retirement — the host syncs once per K tokens; see
  ``repro.serving.continuous``). The JSON carries the engine's dispatch
  accounting (``dispatches_per_token``, ``host_syncs``) so the round-trip
  collapse is measurable, not just inferable from wall clock, plus the
  ``kv_bytes_per_slot`` / ``kv_rows_per_slot`` memory line — the O(window)
  win of ring-KV archs (``--arch <swa-arch>+ring``, e.g.
  ``h2o-danube-1.8b+ring``) is a reported number.

Both engines run the same jit'd model; tokens are counted as each request's
``max_new_tokens`` (useful tokens only — lock-step's over-generated padding
rows don't count). Emits a ``BENCH_serving.json`` summary.

Cross-attention archs (whisper-small, llama-3.2-vision-90b) get a mixed
trace of source-bearing requests with **heterogeneous source lengths**
(``--source-min/--source-max``) and shared source ids
(``--source-share N``: N consecutive requests per source — think N
questions about one image). The continuous engine serves them through the
source-KV pool (one encoder ingest per distinct source id, refcount-shared;
``source_ingests`` / ``source_shares`` land in the JSON) while lock-step
re-encodes per group — both paths mask per-row source lengths, so rows with
different encoder lengths batch together on either engine.

``--arch`` takes a comma-separated list (the JSON becomes a list of per-arch
results), and ``--verify`` re-checks the continuous engine's greedy outputs
token-for-token against per-request ``ServingEngine.generate`` (each
cross-attention request replayed with its own padded + length-masked
source) — the per-request-equivalence contract that covers the
recurrent-state (rwkv6-3b, hymba-1.5b) and MoE (olmoe-1b-7b) families and
holds at every tick horizon.

Every BENCH entry is stamped with ``schema_version``, the arch/config, the
trace seed, the jax version, and ``git describe`` so
``benchmarks/check_regression.py`` can gate fresh runs against the
checked-in ``benchmarks/baselines/*.json`` (and refuse cross-schema or
cross-workload comparisons). ``--trace-out x.trace.json`` adds a third
interleaved pass with telemetry enabled: the enabled-vs-disabled
throughput delta is reported (and gated under ``--check``) as the
telemetry overhead, the token streams are checked identical, and the
pass's event stream is written as a Chrome/Perfetto trace.

``--verify-agreement`` is the quantized (``+w4a8``) leg's replacement for
``--verify``: quantized decode is deliberately not token-exact vs fp32, so
instead of equality it scores greedy token **agreement** between the
continuous engine and per-request lock-step generation on the *same*
quantized model (both engines quantize identically, so this isolates
batching effects from quantization noise), gates it at
``AGREEMENT_TARGET`` under ``--check``, and reports the quantized
``kv_bytes_per_slot`` as a ratio of the fp32 twin's (same arch minus the
``+w4a8`` axis; gated at ``KV_RATIO_TARGET``) plus an informational
prefill-logits MAE probe vs the fp32 twin — the ``quant`` section of the
JSON, pinned by ``check_regression.py`` like the other legs.

``--faults`` switches to the chaos leg: fault-free run, seeded-FaultPlan
run, and exact replay on one engine (invariant auditor on), gating
victim-only quarantine, unaffected-stream byte-identity, deterministic
replay, and zero slot/source leaks — the recovery contract as a pinned
regression surface (``bench: "serving_chaos"``).

    PYTHONPATH=src python benchmarks/serving_bench.py --reduced
    PYTHONPATH=src python benchmarks/serving_bench.py --reduced --verify \
        --arch rwkv6-3b,hymba-1.5b,olmoe-1b-7b --decode-ticks 8
    PYTHONPATH=src python benchmarks/serving_bench.py --reduced --check \
        --verify --faults --trace-shape bursty --rate 200 \
        --json BENCH_serving_chaos.json
    PYTHONPATH=src python benchmarks/serving_bench.py --reduced --verify \
        --arch whisper_small --json BENCH_serving_xattn.json
    PYTHONPATH=src python benchmarks/serving_bench.py --reduced \
        --trace-out serving.trace.json   # open at https://ui.perfetto.dev
"""
from __future__ import annotations

import argparse
import json
import subprocess
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.api import build_model, needs_source
from repro.serving import (ContinuousBatchingEngine, EngineAuditor,
                           FaultPlan, ServingEngine, Telemetry,
                           poisson_trace)
from repro.serving.workload import TRACE_SHAPES

SPEEDUP_TARGET = 1.3
# quant (+w4a8) leg gates: greedy continuous-vs-lockstep token agreement on
# the same quantized model, and the int8 cache's byte footprint vs the fp32
# twin (int8 rows + bf16 scales = 0.25 + 0.5/Dh — 0.28125 at the reduced
# configs' Dh = 16, under the 0.3x budget)
AGREEMENT_TARGET = 0.90
KV_RATIO_TARGET = 0.3
# BENCH entry schema, stamped into every JSON so check_regression.py can
# refuse cross-schema comparisons (keep in sync with
# benchmarks/check_regression.py; bump on any semantic change to entries)
SCHEMA_VERSION = 2
TELEMETRY_OVERHEAD_MAX_PCT = 3.0


def _git_describe() -> str:
    try:
        out = subprocess.run(
            ["git", "describe", "--always", "--dirty"],
            capture_output=True, text=True, timeout=10,
            cwd=Path(__file__).resolve().parent).stdout.strip()
        return out or "unknown"
    except Exception:
        return "unknown"


def _padded_sources(group, src_max, d_model, n_rows):
    """Right-pad a group's heterogeneous sources to [n_rows, src_max, d]
    plus the [n_rows] true lengths (lock-step's uniform-shape form of what
    the continuous engine masks per slot)."""
    src = np.zeros((n_rows, src_max, d_model), np.float32)
    lens = np.zeros((n_rows,), np.int32)
    for j, r in enumerate(group):
        if r.source is not None:
            src[j, :len(r.source)] = r.source
            lens[j] = len(r.source)
    return jnp.asarray(src), jnp.asarray(lens)


def lockstep_runner(model, params, trace, *, n_slots, max_len, pad_id=0):
    """One timed lock-step pass: FIFO groups of ``n_slots``, prompts padded
    to the trace-wide max (one prefill compile), each group decoding
    max(max_new) steps. Cross-attention traces pad each group's sources to
    the pool row size and mask per-row lengths (and the encoder reruns per
    group even when requests share a source — the padding + convoy +
    re-encode waste continuous batching removes). Returns a closure so
    passes can interleave with the continuous engine's (shared host-load
    phases hit both fairly)."""
    cfg = model.cfg
    with_src = needs_source(cfg) and any(r.source is not None for r in trace)
    src_max = cfg.source_len if with_src else None
    eng = ServingEngine(model, params, max_len=max_len, batch=n_slots,
                        source_len=src_max)
    pmax = max(len(r.prompt) for r in trace)
    warm_kw = {}
    if with_src:
        warm_kw = dict(source=jnp.zeros((n_slots, src_max, cfg.d_model),
                                        jnp.float32),
                       source_len=jnp.zeros((n_slots,), jnp.int32))
    # warmup/compile with the shapes the timed loop uses
    eng.generate(jnp.full((n_slots, pmax), pad_id, jnp.int32), steps=2,
                 **warm_kw)

    def one_pass():
        t0 = time.perf_counter()
        useful = 0
        for i in range(0, len(trace), n_slots):
            group = trace[i:i + n_slots]
            prompts = np.full((n_slots, pmax), pad_id, np.int32)
            for j, r in enumerate(group):
                prompts[j, :len(r.prompt)] = r.prompt  # right-pad to uniform
            kw = {}
            if with_src:
                kw["source"], kw["source_len"] = _padded_sources(
                    group, src_max, cfg.d_model, n_slots)
            steps = max(r.max_new_tokens for r in group)
            out = eng.generate(jnp.asarray(prompts), steps=steps, **kw)
            jax.block_until_ready(out)
            useful += sum(r.max_new_tokens for r in group)
        wall = time.perf_counter() - t0
        return {"wall_s": round(wall, 3),
                "tokens_per_s": round(useful / wall, 1),
                "useful_tokens": useful,
                "groups": -(-len(trace) // n_slots),
                "padded_prompt_len": pmax}
    return one_pass


def continuous_runner(model, params, trace, *, n_slots, max_len, chunk, seed,
                      decode_ticks, telemetry=None):
    eng = ContinuousBatchingEngine(model, params, n_slots=n_slots,
                                   max_len=max_len, chunk=chunk, seed=seed,
                                   decode_ticks=decode_ticks,
                                   telemetry=telemetry)
    eng.warmup()
    holder = {}

    def one_pass():
        report = eng.run([r for r in trace])
        holder["report"] = report      # full per-request report for --verify
        return report["aggregate"]
    one_pass.holder = holder
    return one_pass


def verify_equivalence(model, params, trace, report, *, max_len) -> list:
    """Greedy continuous-batching outputs must equal per-request lock-step
    generation token-for-token; returns the rids that differ. Cross-
    attention requests replay each with its own (padded + length-masked)
    source, so heterogeneous-source batching must also be invisible."""
    cfg = model.cfg
    with_src = needs_source(cfg) and any(r.source is not None for r in trace)
    ref = ServingEngine(model, params, max_len=max_len, batch=1,
                        source_len=cfg.source_len if with_src else None)
    by_rid = {r["rid"]: r for r in report["requests"]}
    bad = []
    for req in trace:
        kw = {}
        if with_src and req.source is not None:
            kw["source"], kw["source_len"] = _padded_sources(
                [req], cfg.source_len, cfg.d_model, 1)
        want = np.asarray(ref.generate(jnp.asarray(req.prompt)[None],
                                       steps=req.max_new_tokens, **kw))[0]
        if by_rid[req.rid]["tokens"] != want.tolist():
            bad.append(req.rid)
    return bad


def verify_agreement(model, params, trace, report, *, max_len) -> tuple:
    """Quantized (``+w4a8``) twin of :func:`verify_equivalence`: score the
    continuous engine's greedy outputs against per-request lock-step
    generation on the **same quantized model** as a token agreement rate
    instead of demanding equality. Both engines quantize the same params in
    ``__init__``, so single-chunk prompts agree bit-exactly and multi-chunk
    prompts diverge only through the chunked prefill's int8 prefix re-read
    (see tests/test_serving_conformance.py for the two-tier contract).
    Returns ``(rate, matched, total)``."""
    cfg = model.cfg
    with_src = needs_source(cfg) and any(r.source is not None for r in trace)
    ref = ServingEngine(model, params, max_len=max_len, batch=1,
                        source_len=cfg.source_len if with_src else None)
    by_rid = {r["rid"]: r for r in report["requests"]}
    matched = total = 0
    for req in trace:
        kw = {}
        if with_src and req.source is not None:
            kw["source"], kw["source_len"] = _padded_sources(
                [req], cfg.source_len, cfg.d_model, 1)
        want = np.asarray(ref.generate(jnp.asarray(req.prompt)[None],
                                       steps=req.max_new_tokens, **kw))[0]
        got = by_rid[req.rid]["tokens"]
        matched += sum(a == b for a, b in zip(got, want.tolist()))
        total += len(want)
    return (matched / total if total else 1.0), matched, total


def _kv_bytes_per_slot(eng) -> int:
    """Static per-slot KV footprint of an engine's cache — the same key
    set and arithmetic as ``ContinuousBatchingEngine.report()``, usable on
    a freshly built (never run) fp32 twin engine."""
    kv = [eng.cache[k] for k in ("k", "v", "k_scale", "v_scale",
                                 "cross_k", "cross_v", "src_k", "src_v",
                                 "src_k_scale", "src_v_scale")
          if k in eng.cache]
    return sum(int(a.size) * a.dtype.itemsize for a in kv) // eng.pool.n_slots


def quant_mae_probe(model, params, vocab_size: int) -> float:
    """Informational fp32-twin comparison: prefill-logits MAE on a seeded
    probe batch, normalized by the fp32 logit spread. Free-running token
    agreement vs fp32 cliffs on top-2 gaps (MoE routing, small-vocab
    reduced configs), so the fp32 comparison is pinned where quantization
    actually bounds something; the serving-level gate is
    :func:`verify_agreement` on the quantized pair."""
    from repro.models.quantized import quantize_params
    qparams = quantize_params(params)
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, vocab_size, (4, 16)), jnp.int32)
    cache_fp = model.init_cache(4, 64, kv_dtype=jnp.float32)
    cache_q = model.init_cache(4, 64, kv_dtype=jnp.int8)
    lf, _ = jax.jit(model.prefill)(params, prompts, cache_fp, None, None)
    lq, _ = jax.jit(model.prefill)(qparams, prompts, cache_q, None, None)
    lf = np.asarray(lf, np.float64)
    lq = np.asarray(lq, np.float64)
    return float(np.abs(lq - lf).mean() / lf.std())


def best_of_interleaved(runners: dict, repeats: int) -> tuple[dict, list]:
    """Alternate one pass per engine, ``repeats`` rounds; keep each engine's
    fastest pass. Interleaving means a slow host phase degrades the same
    round of every engine instead of one engine's whole measurement. Also
    returns the per-round results (``rounds[i][name]``) so paired same-round
    comparisons — e.g. the telemetry overhead gate — can cancel host drift
    instead of comparing two independent bests."""
    best: dict = {}
    rounds: list[dict] = []
    for _ in range(repeats):
        this_round: dict = {}
        for name, one_pass in runners.items():
            res = one_pass()
            this_round[name] = res
            if name not in best or res["wall_s"] < best[name]["wall_s"]:
                best[name] = res
        rounds.append(this_round)
    return best, rounds


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-7b",
                    help="architecture name, or a comma-separated list")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--n-slots", type=int, default=4)
    ap.add_argument("--requests", type=int, default=32,
                    help="trace length; short traces make the tail-drain "
                         "phase (slots emptying) dominate occupancy")
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--chunk", type=int, default=32,
                    help="prefill chunk; 32 halves per-chunk call overhead "
                         "vs 16 on this trace's prompt mix")
    ap.add_argument("--prompt-min", type=int, default=8)
    ap.add_argument("--prompt-max", type=int, default=48)
    ap.add_argument("--gen-min", type=int, default=4)
    ap.add_argument("--gen-max", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace-shape", default="poisson",
                    choices=list(TRACE_SHAPES),
                    help="interarrival shape (bursty / heavy-tail stress "
                         "the queue; default poisson keeps pre-existing "
                         "baselines comparable)")
    ap.add_argument("--rate", type=float, default=None,
                    help="mean arrival rate req/s (default: backlogged)")
    ap.add_argument("--faults", action="store_true",
                    help="chaos mode: run the continuous engine fault-free, "
                         "then under a seeded FaultPlan, then replay the "
                         "plan — checks victim-only quarantine, unaffected-"
                         "stream byte-identity, deterministic replay, and "
                         "zero slot/source leaks (auditor on throughout). "
                         "Replaces the lockstep-vs-continuous comparison")
    ap.add_argument("--fault-seed", type=int, default=0,
                    help="chaos mode: FaultPlan.random seed")
    ap.add_argument("--n-faults", type=int, default=3,
                    help="chaos mode: faults per plan")
    ap.add_argument("--source-min", type=int, default=0,
                    help="cross-attention archs: min source rows per "
                         "request (default: source_len // 4)")
    ap.add_argument("--source-max", type=int, default=0,
                    help="cross-attention archs: max source rows per "
                         "request (default: the config's source_len)")
    ap.add_argument("--source-share", type=int, default=2,
                    help="cross-attention archs: consecutive requests "
                         "sharing one source id (the pool serves shares "
                         "by refcount — source_ingests/source_shares in "
                         "the JSON); 1 disables sharing")
    ap.add_argument("--decode-ticks", type=int, default=8,
                    help="fused decode ticks per dispatch (K): the host "
                         "syncs once per K tokens; on-device retirement "
                         "keeps per-request outputs exact at any K")
    ap.add_argument("--repeats", type=int, default=3,
                    help="timed passes per engine; best taken")
    ap.add_argument("--json", default="BENCH_serving.json")
    ap.add_argument("--check", action="store_true",
                    help=f"exit non-zero unless speedup >= {SPEEDUP_TARGET}x")
    ap.add_argument("--verify", action="store_true",
                    help="check continuous greedy outputs token-for-token "
                         "against per-request generation (exit non-zero on "
                         "any mismatch)")
    ap.add_argument("--verify-agreement", action="store_true",
                    help="quantized (+w4a8) archs: score continuous greedy "
                         "outputs against per-request generation on the "
                         "same quantized model as a token agreement rate "
                         f"(--check gates >= {AGREEMENT_TARGET}), and "
                         "report kv_bytes_per_slot as a ratio of the fp32 "
                         f"twin's (--check gates <= {KV_RATIO_TARGET}x) "
                         "plus an informational logits-MAE probe")
    ap.add_argument("--trace-out", default=None,
                    help="write a Chrome/Perfetto trace (.trace.json) of a "
                         "telemetry-enabled continuous pass, and report the "
                         "telemetry overhead (enabled vs disabled "
                         "throughput; --check gates it at "
                         f"{TELEMETRY_OVERHEAD_MAX_PCT}%%). With multiple "
                         "archs the arch name is appended to the stem")
    args = ap.parse_args(argv)

    archs = [a.strip() for a in args.arch.split(",")]
    results, rc = [], 0
    for arch in archs:
        trace_out = None
        if args.trace_out:
            p = Path(args.trace_out)
            trace_out = (p if len(archs) == 1
                         else p.with_name(f"{p.stem}.{arch}{p.suffix}"))
        result, arch_rc = (run_chaos(arch, args) if args.faults
                           else run_arch(arch, args, trace_out=trace_out))
        results.append(result)
        rc = max(rc, arch_rc)

    out = Path(args.json)
    out.write_text(json.dumps(results[0] if len(results) == 1 else results,
                              indent=1))
    print(f"wrote {out}")
    return rc


def setup_arch(arch: str, args):
    """Shared per-arch setup: config, model, params, and the feasible
    seeded trace (same filters for every bench mode, so a chaos run and a
    perf run over the same flags replay the identical workload)."""
    cfg = get_config(arch, reduced=args.reduced)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    src_kw, src_range = {}, None
    if needs_source(cfg):
        # cross-attention trace: heterogeneous source lengths + shared
        # source ids, the mixed shape vision/audio traffic has. Clamped to
        # the config's source_len (the pool row size): an oversized source
        # would be rejected by the continuous engine and overflow the
        # lock-step padding — infeasible on both engines, so it never
        # enters the trace (mirrors the prompt-budget feasibility filter)
        hi = min(args.source_max or cfg.source_len, cfg.source_len)
        src_range = (min(args.source_min or max(1, cfg.source_len // 4), hi),
                     hi)
        src_kw = dict(source_len=src_range, source_dim=cfg.d_model,
                      source_share=args.source_share)
    trace = poisson_trace(
        n_requests=args.requests, vocab_size=cfg.vocab_size,
        rate=args.rate, shape=args.trace_shape,
        prompt_len=(args.prompt_min, args.prompt_max),
        max_new=(args.gen_min, args.gen_max), seed=args.seed, **src_kw)
    # both engines must see the identical feasible workload: a request the
    # continuous engine would reject (slot capacity), or whose budget plus
    # the trace-wide padded prompt trips lock-step's p + steps <= max_len
    # assert, skews the comparison
    feasible = [r for r in trace
                if len(r.prompt) + r.max_new_tokens <= args.max_len - 1]
    pmax = max((len(r.prompt) for r in feasible), default=0)
    feasible = [r for r in feasible
                if pmax + r.max_new_tokens <= args.max_len]
    if len(feasible) < len(trace):
        print(f"  [note] dropped {len(trace) - len(feasible)} requests "
              f"exceeding max_len {args.max_len} budget")
    return cfg, model, params, feasible, src_range


def _entry_stamp(cfg, args, trace, src_range) -> dict:
    """The identity keys check_regression.py compares fresh vs baseline on.
    ``trace_shape`` / ``rate`` appear only when non-default so pre-existing
    baselines (generated before the knobs existed) stay comparable."""
    return {
        "schema_version": SCHEMA_VERSION,
        "meta": {
            "seed": args.seed, "arch_list": args.arch,
            "config": cfg.name, "jax_version": jax.__version__,
            "git_describe": _git_describe(),
        },
        "arch": cfg.name, "reduced": args.reduced,
        "n_slots": args.n_slots, "n_requests": len(trace),
        "max_len": args.max_len, "chunk": args.chunk,
        "decode_ticks": args.decode_ticks,
        "prompt_len": [args.prompt_min, args.prompt_max],
        "max_new": [args.gen_min, args.gen_max],
        **({"trace_shape": args.trace_shape}
           if args.trace_shape != "poisson" else {}),
        **({"rate": args.rate} if args.rate is not None else {}),
        **({"source_len": list(src_range),
            "source_share": args.source_share} if src_range else {}),
    }


def run_arch(arch: str, args, trace_out: Path | None = None
             ) -> tuple[dict, int]:
    cfg, model, params, trace, src_range = setup_arch(arch, args)

    print(f"[serving_bench] {cfg.name} reduced={args.reduced} "
          f"slots={args.n_slots} requests={len(trace)}")
    cont_runner = continuous_runner(model, params, trace,
                                    n_slots=args.n_slots,
                                    max_len=args.max_len,
                                    chunk=args.chunk, seed=args.seed,
                                    decode_ticks=args.decode_ticks)
    runners = {
        "lockstep": lockstep_runner(model, params, trace,
                                    n_slots=args.n_slots,
                                    max_len=args.max_len),
        "continuous": cont_runner,
    }
    tel = tel_runner = None
    if trace_out is not None:
        # a third interleaved engine with telemetry enabled: same trace,
        # same jits — the enabled-vs-disabled throughput delta IS the
        # telemetry overhead, measured not asserted
        tel = Telemetry()
        tel_runner = continuous_runner(model, params, trace,
                                       n_slots=args.n_slots,
                                       max_len=args.max_len,
                                       chunk=args.chunk, seed=args.seed,
                                       decode_ticks=args.decode_ticks,
                                       telemetry=tel)
        runners["continuous+telemetry"] = tel_runner
    best, rounds = best_of_interleaved(runners, args.repeats)
    lock, cont = best["lockstep"], best["continuous"]
    print(f"  lock-step:  {lock['tokens_per_s']:8.1f} tok/s "
          f"({lock['wall_s']}s, {lock['groups']} groups padded to "
          f"{lock['padded_prompt_len']})")
    print(f"  continuous: {cont['tokens_per_s']:8.1f} tok/s "
          f"({cont['wall_s']}s, occupancy {cont['mean_occupancy']}, "
          f"ttft p50 {cont['ttft_p50_s']}s, decode_ticks "
          f"{args.decode_ticks}, {cont['dispatches_per_token']} "
          f"dispatches/token, {cont['host_syncs']} host syncs)")
    # the O(window) accounting line: ring archs hold kv_rows_per_slot ==
    # ring_len << max_len live KV rows per slot
    print(f"  kv cache:   {cont['kv_bytes_per_slot']} B/slot "
          f"({cont['kv_rows_per_slot']} rows/slot, max_len "
          f"{cont['max_len']})")
    if "source_ingests" in cont:
        print(f"  source kv:  {cont['source_ingests']} ingests, "
              f"{cont['source_shares']} shares "
              f"({cont['src_rows_per_entry']} rows/entry)")

    speedup = round(cont["tokens_per_s"] / lock["tokens_per_s"], 3)
    status = "PASS" if speedup >= SPEEDUP_TARGET else "MISS"
    print(f"  speedup: {speedup}x (target {SPEEDUP_TARGET}x) [{status}]")

    rc = 0 if (speedup >= SPEEDUP_TARGET or not args.check) else 1
    telemetry_info = None
    if trace_out is not None:
        tel_best = best["continuous+telemetry"]
        # paired same-round comparison: each interleaved round ran both
        # engines under the same host conditions, so the per-round ratio
        # cancels drift; the min over rounds bounds the intrinsic overhead
        # (noise can only inflate a round's ratio, never deflate it). The
        # run is sub-second, so host jitter swamps the true cost at 3
        # rounds — run extra back-to-back pairs until the bound stabilizes
        ratios = [(1 - r["continuous+telemetry"]["tokens_per_s"]
                   / r["continuous"]["tokens_per_s"]) * 100
                  for r in rounds]
        for _ in range(max(0, 7 - len(ratios))):
            if min(ratios) <= 0.0:
                break                        # already at/below parity
            pair = {"continuous": cont_runner()["tokens_per_s"],
                    "continuous+telemetry": tel_runner()["tokens_per_s"]}
            ratios.append(
                (1 - pair["continuous+telemetry"] / pair["continuous"])
                * 100)
        # a negative min means the pair ran at parity within noise
        overhead = round(max(0.0, min(ratios)), 2)
        same = (
            {r["rid"]: r["tokens"]
             for r in cont_runner.holder["report"]["requests"]}
            == {r["rid"]: r["tokens"]
                for r in tel_runner.holder["report"]["requests"]})
        tel.write_chrome_trace(trace_out)
        telemetry_info = {
            "overhead_pct": overhead, "overhead_max_pct":
            TELEMETRY_OVERHEAD_MAX_PCT, "tokens_identical": same,
            "events": len(tel.events), "trace_out": str(trace_out),
            "tokens_per_s_enabled": tel_best["tokens_per_s"],
        }
        tel_ok = overhead <= TELEMETRY_OVERHEAD_MAX_PCT and same
        print(f"  telemetry:  overhead {overhead}% paired-min "
              f"(best {tel_best['tokens_per_s']} vs {cont['tokens_per_s']} "
              f"tok/s; max {TELEMETRY_OVERHEAD_MAX_PCT}%), tokens "
              f"identical: {same}, {len(tel.events)} events -> {trace_out} "
              f"[{'PASS' if tel_ok else 'FAIL'}]")
        if args.check and not tel_ok:
            rc = 1
    quant_info = None
    if args.verify_agreement:
        if not cfg.w4a8_serve:
            print(f"  [note] --verify-agreement skipped: {cfg.name} has no "
                  "+w4a8 axis (use --verify for exact equivalence)")
        else:
            rate, matched, total = verify_agreement(
                model, params, trace, cont_runner.holder["report"],
                max_len=args.max_len)
            # fp32 twin: the same arch minus the +w4a8 axis — its (never
            # run) engine's cache is the denominator of the byte ratio
            base_arch = arch.replace("+w4a8", "")
            t_cfg = get_config(base_arch, reduced=args.reduced)
            t_eng = ContinuousBatchingEngine(
                build_model(t_cfg), params, n_slots=args.n_slots,
                max_len=args.max_len, chunk=args.chunk, seed=args.seed,
                decode_ticks=args.decode_ticks)
            fp_bytes = _kv_bytes_per_slot(t_eng)
            ratio = round(cont["kv_bytes_per_slot"] / fp_bytes, 4)
            mae = round(quant_mae_probe(model, params, cfg.vocab_size), 4)
            quant_ok = rate >= AGREEMENT_TARGET and ratio <= KV_RATIO_TARGET
            quant_info = {
                "agreement_rate": round(rate, 4),
                "agreement_matched": matched,
                "agreement_total": total,
                "agreement_target": AGREEMENT_TARGET,
                "kv_bytes_per_slot_fp32": fp_bytes,
                "kv_bytes_ratio": ratio,
                "kv_ratio_max": KV_RATIO_TARGET,
                "logits_mae_over_spread": mae,     # informational
            }
            print(f"  quant: agreement {rate:.4f} ({matched}/{total} "
                  f"tokens, floor {AGREEMENT_TARGET}), kv bytes "
                  f"{cont['kv_bytes_per_slot']} vs fp32 twin {fp_bytes} "
                  f"= {ratio}x (max {KV_RATIO_TARGET}x), logits MAE/spread "
                  f"{mae} [{'PASS' if quant_ok else 'FAIL'}]")
            if args.check and not quant_ok:
                rc = 1
    result = {
        "bench": "serving_continuous_vs_lockstep",
        **_entry_stamp(cfg, args, trace, src_range),
        "lockstep": lock, "continuous": cont,
        "speedup_tokens_per_s": speedup,
        "speedup_target": SPEEDUP_TARGET,
        **({"quant": quant_info} if quant_info else {}),
        **({"telemetry": telemetry_info} if telemetry_info else {}),
    }
    if args.verify:
        bad = verify_equivalence(model, params, trace,
                                 cont_runner.holder["report"],
                                 max_len=args.max_len)
        result["verify_mismatched_rids"] = bad
        print(f"  verify: {len(trace) - len(bad)}/{len(trace)} requests "
              f"token-for-token equal to per-request generation "
              f"[{'PASS' if not bad else 'FAIL: ' + str(bad)}]")
        rc = max(rc, 1 if bad else 0)
    return result, rc


def run_chaos(arch: str, args) -> tuple[dict, int]:
    """Chaos leg (``--faults``): one continuous engine with the invariant
    auditor on, three runs over the identical trace — fault-free, under a
    seeded :class:`FaultPlan`, and a replay of the same plan — then the
    recovery contract, checked not asserted:

    * only the plan's fired victims end ERRORED (victim-only quarantine);
    * every non-victim token stream is byte-identical to the fault-free
      run, and each victim's partial stream is a prefix of its fault-free
      stream;
    * the replay run reproduces the faulted run exactly (tokens + errored
      set) — fault handling is deterministic, so failures are debuggable;
    * zero slot / source-entry leaks after the faulted run, and a full
      post-run auditor check passes.

    All gates are deterministic for a given (seed, fault-seed) pair, so
    ``check_regression.py`` pins them exactly against the checked-in
    ``BENCH_serving_chaos.json`` baseline."""
    cfg, model, params, trace, src_range = setup_arch(arch, args)
    print(f"[serving_bench --faults] {cfg.name} reduced={args.reduced} "
          f"slots={args.n_slots} requests={len(trace)} "
          f"shape={args.trace_shape}")
    auditor = EngineAuditor()
    eng = ContinuousBatchingEngine(
        model, params, n_slots=args.n_slots, max_len=args.max_len,
        chunk=args.chunk, seed=args.seed, decode_ticks=args.decode_ticks,
        auditor=auditor)
    eng.warmup()
    clean = eng.run(list(trace))

    kinds = ("poison_nan", "dispatch_fail", "tick_delay")
    if needs_source(cfg):
        kinds += ("ingest_fail",)
    # max_block=0: every fault fires at its seam's first opportunity —
    # poison at the victim's first decode block — so the fired set is
    # request-relative and stays deterministic under timed bursty arrivals
    plan = FaultPlan.random(args.fault_seed, [r.rid for r in trace],
                            n_faults=args.n_faults, kinds=kinds, max_block=0)
    eng.faults = plan
    faulted = eng.run(list(trace))
    eng.faults = plan.replay()
    replayed = eng.run(list(trace))
    eng.faults = None

    def toks(report):
        return {r["rid"]: r["tokens"] for r in report["requests"]}

    def errored(report):
        return sorted(r["rid"] for r in report["requests"]
                      if r["status"] == "errored")

    ct, ft, rt = toks(clean), toks(faulted), toks(replayed)
    victims = sorted(plan.victims())
    err = errored(faulted)
    victim_only = err == victims
    unaffected = all(ft[rid] == t for rid, t in ct.items()
                     if rid not in victims)
    prefix_ok = all(ft[rid] == ct[rid][:len(ft[rid])] for rid in victims)
    replay_identical = (ft == rt and err == errored(replayed))
    slot_leaks = eng.pool.n_used
    src_leaks = eng.src_pool.n_used if eng.src_pool is not None else 0
    try:
        auditor.check(eng)
        audit_clean = True
    except AssertionError as e:
        audit_clean = False
        print(f"  [audit] post-run violation: {e}")

    agg = faulted["aggregate"]
    chaos = {
        "plan": plan.to_json(),
        "victims": victims, "errored": err,
        "n_errored": agg["n_errored"], "n_shed": agg["n_shed"],
        "generated_tokens": agg["generated_tokens"],
        "faults_fired": agg["faults_fired"],
        "dispatch_retries": agg.get("dispatch_retries", 0),
        "audit_checks": agg["audit_checks"],
        "victim_only_quarantine": victim_only,
        "unaffected_identical": unaffected,
        "victim_prefix_ok": prefix_ok,
        "replay_identical": replay_identical,
        "slot_leaks": slot_leaks, "src_leaks": src_leaks,
        "audit_clean": audit_clean,
    }
    ok = (victim_only and unaffected and prefix_ok and replay_identical
          and slot_leaks == 0 and src_leaks == 0 and audit_clean
          and agg["audit_checks"] > 0)
    print(f"  plan: {plan!r} -> victims {victims}, errored {err} "
          f"[{'OK' if victim_only else 'FAIL'}]")
    print(f"  recovery: unaffected identical {unaffected}, victim prefix "
          f"{prefix_ok}, replay identical {replay_identical}")
    print(f"  ledger: {slot_leaks} slot leaks, {src_leaks} source leaks, "
          f"{agg['audit_checks']} audit checks, clean {audit_clean}")
    print(f"  tokens: {agg['generated_tokens']} retired "
          f"({agg['n_errored']} errored, {agg['n_shed']} shed, "
          f"{chaos['dispatch_retries']} dispatch retries) "
          f"[{'PASS' if ok else 'FAIL'}]")

    rc = 0 if (ok or not args.check) else 1
    result = {
        "bench": "serving_chaos",
        **_entry_stamp(cfg, args, trace, src_range),
        "fault_seed": args.fault_seed, "n_faults": args.n_faults,
        "clean": clean["aggregate"], "faulted": agg,
        "chaos": chaos,
    }
    if args.verify:
        bad = verify_equivalence(model, params, trace, clean,
                                 max_len=args.max_len)
        result["verify_mismatched_rids"] = bad
        print(f"  verify: {len(trace) - len(bad)}/{len(trace)} fault-free "
              f"requests token-for-token equal to per-request generation "
              f"[{'PASS' if not bad else 'FAIL: ' + str(bad)}]")
        rc = max(rc, 1 if bad else 0)
    return result, rc


if __name__ == "__main__":
    sys.exit(main())
