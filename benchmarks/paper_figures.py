"""Reproductions of the paper's tables/figures. One function per artifact;
``benchmarks.run`` executes them all and emits CSV + JSON.

  fig7a  — attention time vs context: SwiftKV vs Flash(8/16/32)   [cycles]
  fig7b  — speedup vs native at ctx 512 (+ CPU wall-clock check)
  table1 — Top-1..5 token agreement, W4A8+FXP32 vs fp32
  lut    — Eq. 9-10 LUT exp max relative error (paper: 0.00586%)
  fxp    — §III FXP32 attention precision (paper: better than 1e-5)
  fig8a  — decode latency breakdown; attention share (paper: 3.19%,
           13.48x less than the 43% of [5])
  table3 — tokens/s + ms/token for LLaMA2-7B / ChatGLM-6B (paper: 81.5 /
           96.3 tok/s)
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from . import edge_cost_model as ecm


# ---------------------------------------------------------------------------
# Fig. 7a — attention computation time vs context length
# ---------------------------------------------------------------------------

def fig7a_context_sweep() -> dict:
    ctxs = [64, 128, 256, 512, 1024, 2048, 4096]
    rows = []
    for n in ctxs:
        rows.append({
            "ctx": n,
            "swiftkv_us": ecm.swiftkv_cycles(n) / ecm.CLOCK_HZ * 1e6,
            "flash8_us": ecm.flash_cycles(n, 8) / ecm.CLOCK_HZ * 1e6,
            "flash16_us": ecm.flash_cycles(n, 16) / ecm.CLOCK_HZ * 1e6,
            "flash32_us": ecm.flash_cycles(n, 32) / ecm.CLOCK_HZ * 1e6,
        })
    # paper claim: SwiftKV below every Flash curve at every context
    always_below = all(r["swiftkv_us"] < min(r["flash8_us"], r["flash16_us"],
                                             r["flash32_us"]) for r in rows)
    return {"rows": rows, "swiftkv_always_fastest": always_below}


# ---------------------------------------------------------------------------
# Fig. 7b — speedup over native attention at ctx 512
# ---------------------------------------------------------------------------

def fig7b_speedup() -> dict:
    model = ecm.speedups_at(512)
    paper = {"native": 1.0, "flash32": 1.46, "streaming": 2.15,
             "swiftkv": 7.16}
    # CPU wall-clock cross-check of our jitted implementations: the same
    # single-pass-vs-two-pass ordering must hold on a real machine too.
    from repro.core import swiftkv as sk
    d, n = 128, 512
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal(d), jnp.float32)
    k = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)

    def bench(fn, reps=20):
        out = fn(q, k, v)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(reps):
            out = fn(q, k, v)
        jax.block_until_ready(out)
        return (time.perf_counter() - t0) / reps * 1e6

    blockwise = jax.jit(lambda *a: sk.swiftkv_decode_blockwise(*a,
                                                               block_size=128))
    naive = jax.jit(sk.softmax_attention_reference)
    cpu = {"blockwise_us": bench(blockwise), "naive_us": bench(naive)}
    return {"model": {k2: round(v2, 2) for k2, v2 in model.items()},
            "paper": paper,
            "calibration": ecm.calibrate(),
            "cpu_wall_clock": cpu}


# ---------------------------------------------------------------------------
# Table I — Top-k token agreement under W4A8 + FXP32 attention
# ---------------------------------------------------------------------------

def table1_topk_agreement(n_positions: int = 64, train_steps: int = 60) -> dict:
    """The paper samples PG-19 through LLaMA2-7B on the FPGA and compares
    Top-1..5 logits against a desktop run at the same W4A8 precision. Our
    analogue: a reduced llama2-family model briefly trained (random-init
    logits are near-uniform — agreement would be meaningless), then the same
    forward run two ways:
      fp32 reference   : f32 weights, f32 attention
      edge pipeline    : W4A8 quantized projections (group-128 int4 weights,
                         per-token int8 activations) + SwiftKV attention
    and Top-k sets compared at ``n_positions`` decode positions."""
    from repro.configs import get_config
    from repro.models.api import build_model, lm_loss
    from repro.core.quantization import quantize_w4, w4a8_matmul_ref
    from repro.optim import adamw_init, adamw_update
    from repro.data.pipeline import batch_for_step

    cfg = get_config("llama2_7b", reduced=True).replace(
        compute_dtype="float32")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    opt = adamw_init(params)

    @jax.jit
    def step(params, opt, batch):
        loss, grads = jax.value_and_grad(
            lambda p: lm_loss(model, p, batch["tokens"], batch["labels"],
                              remat=False))(params)
        params, opt, _ = adamw_update(params, grads, opt,
                                      lr=jnp.float32(3e-3))
        return params, opt, loss

    for s in range(train_steps):
        params, opt, loss = step(params, opt,
                                 batch_for_step(cfg.vocab_size, 32, 8, 0, s))

    # quantize every 2-D projection matrix to W4A8-applied form
    def quantize_tree(p):
        def q(leaf):
            if (leaf.ndim == 2 and leaf.shape[0] >= 32
                    and leaf.shape[1] % 2 == 0      # nibble packing needs even N
                    and "float" in str(leaf.dtype)):
                qw = quantize_w4(leaf)
                from repro.core.quantization import dequantize_w4
                return dequantize_w4(qw)  # weight-quant error, fp math
            return leaf
        return jax.tree.map(q, p)

    params_q = quantize_tree(params)

    batch = batch_for_step(cfg.vocab_size, 32, 8, 1, 999)
    logits_ref, _ = model.forward(params, batch["tokens"], remat=False)
    logits_q, _ = model.forward(params_q, batch["tokens"], remat=False)

    ref = np.asarray(logits_ref.reshape(-1, cfg.vocab_size))[:n_positions]
    got = np.asarray(logits_q.reshape(-1, cfg.vocab_size))[:n_positions]
    agreement = {}
    for k in (1, 2, 3, 5):
        top_ref = np.argsort(-ref, axis=-1)[:, :k]
        top_got = np.argsort(-got, axis=-1)[:, :k]
        same = [set(a) == set(b) for a, b in zip(top_ref, top_got)]
        agreement[f"top{k}"] = float(np.mean(same))
    paper = {"top1": 1.00, "top2": 1.00, "top3": 0.99, "top5": 0.98}
    return {"agreement": agreement, "paper": paper,
            "final_train_loss": float(loss)}


# ---------------------------------------------------------------------------
# LUT exponential error (Eqs. 9-10)
# ---------------------------------------------------------------------------

def lut_exp_error() -> dict:
    from repro.core import exp2_lut, fixedpoint
    float_err = exp2_lut.max_relative_error()
    xs = np.linspace(-0.999999, 0, 100_000)
    got = exp2_lut.exp_lut_fxp(fixedpoint.to_fxp(xs)) / (1 << 17)
    fxp_err = float(np.max(np.abs(got - np.exp(xs)) / np.exp(xs)))
    return {"float_path_max_rel_err": float_err,
            "fxp_path_max_rel_err": fxp_err,
            "paper_max_rel_err": 5.86e-5,
            "reproduced": abs(float_err - 5.86e-5) / 5.86e-5 < 0.05}


# ---------------------------------------------------------------------------
# FXP32 attention precision (§III claim: better than 1e-5)
# ---------------------------------------------------------------------------

def fxp_attention_precision(trials: int = 10) -> dict:
    from repro.core import fixedpoint
    from repro.core.swiftkv import softmax_attention_reference
    rng = np.random.default_rng(0)
    max_err, mean_errs = 0.0, []
    for _ in range(trials):
        d, s = 128, 512
        q = rng.standard_normal(d)
        k = rng.standard_normal((s, d))
        v = rng.standard_normal((s, d))
        got = fixedpoint.swiftkv_attention_fxp(q, k, v)
        want = np.asarray(softmax_attention_reference(
            jnp.asarray(q, jnp.float32), jnp.asarray(k, jnp.float32),
            jnp.asarray(v, jnp.float32)))
        err = np.abs(got - want)
        max_err = max(max_err, float(err.max()))
        mean_errs.append(float(err.mean()))
    return {"max_abs_err": max_err, "mean_abs_err": float(np.mean(mean_errs)),
            "paper_claim": 1e-5,
            "mean_below_claim": float(np.mean(mean_errs)) < 1e-5}


# ---------------------------------------------------------------------------
# Fig. 8a — decode latency breakdown
# ---------------------------------------------------------------------------

def fig8a_breakdown() -> dict:
    swift = ecm.decode_latency_breakdown(ecm.LLAMA2_7B)
    native = ecm.decode_latency_breakdown(ecm.LLAMA2_7B, attention="native")
    return {
        "swiftkv": {k: round(v, 5) for k, v in swift.items()},
        "native_attention": {"attention_share":
                             round(native["attention_share"], 4)},
        "attention_share_paper": 0.0319,
        "reduction_vs_dfx_43pct": round(0.43 / swift["attention_share"], 2),
        "reduction_paper": 13.48,
    }


# ---------------------------------------------------------------------------
# Table III — end-to-end decode tokens/s
# ---------------------------------------------------------------------------

def table3_tokens_per_s() -> dict:
    out = {}
    paper = {"llama2-7b": {"ms": 12.3, "tok_s": 81.5},
             "chatglm-6b": {"ms": 10.4, "tok_s": 96.3}}
    for m in (ecm.LLAMA2_7B, ecm.CHATGLM_6B):
        b = ecm.decode_latency_breakdown(m)
        out[m.name] = {"ms_per_token": round(b["ms_per_token"], 2),
                       "tokens_per_s": round(b["tokens_per_s"], 1),
                       "paper": paper[m.name]}
    # throughput: ops/token x tokens/s (paper: 13.5 GOP x 81.5 = 1100 GOPS)
    gop_per_token = 2 * ecm.LLAMA2_7B.n_params / 1e9
    out["throughput_gops"] = round(
        gop_per_token * out["llama2-7b"]["tokens_per_s"], 1)
    out["throughput_paper_gops"] = 1100.3
    return out
