"""Cycle-accurate cost model of the SwiftKV edge accelerator (paper §III-V).

The paper's Figs. 7-8 and Tables III-IV are FPGA measurements; this container
has no FPGA, so we reproduce them with an explicit cycle model of the SKV
core's resources and of each attention schedule mapped onto the *same*
resources (exactly the paper's experimental setup: "identical set of exp
units and the same pipelined multiply and divide units").

Hardware parameters (from the paper):
  * Public MAC Array: 128 DSPs -> one 32-lane FXP32 dot-product step/cycle,
    i.e. a 128-d q.k_t dot takes DOT = 4 cycles (§IV-B).
  * LUT exponential: EXP_LAT cycles (5-bit LUT + interpolation, Eq. 10 ~3
    pipeline stages).
  * Divider: DIV_LAT cycles (pipelined divide unit).
  * Clock: 225 MHz; HBM: 460 GB/s.

Schedules (decode, per head, context N, head_dim 128):
  * SwiftKV  — per-token pipeline: while q.k_t streams through the MAC array
    (DOT cycles/token), the previous token's compare/exp/update retires in
    the shadow of the dot (§III: "all remaining updates can be scheduled
    within its latency"). One deferred divide at the end.
        cycles = FILL + N * DOT + DIV_LAT + d/LANES
  * Native   — two passes with score materialization and a softmax stage in
    between; no cross-stage pipelining (the conventional GEMM-based mapping,
    Fig. 1): score pass (load+dot per token, serialized), softmax pass
    (max scan, exp per score through the shared exp unit, sum, divide per
    score), PV pass (load + MAC per token, serialized).
  * Flash(B) — blockwise single-unit mapping: a block of B dots pipelines
    (DOT*B), but the blockwise-softmax epilogue (block max, B exps through
    the shared exp units, running rescale of Z and the [d] accumulator, with
    loop-carried dependencies) cannot overlap the next block's dots on one
    hardware set -> per-block stall (the paper's "forcing the computation to
    wait for block").
  * Streaming — two-pass online softmax (ITA-style [15]): pass 1 dots
    pipelined with running max/sum, pass 2 recomputes exp and accumulates PV
    (exp on the critical path of pass 2).

Free parameters EXP_LAT and DIV_LAT are calibrated once against Fig. 7(b)'s
three reported ratios (native 1x, Flash32 1.46x, Streaming 2.15x, SwiftKV
7.16x at N=512) — see ``calibrate()``; everything else is derived from the
paper's stated microarchitecture.
"""
from __future__ import annotations

from dataclasses import dataclass

D_HEAD = 128
LANES = 32          # FXP32 dot lanes/cycle (128 DSPs / 4 per FXP32 mult)
DOT = D_HEAD // LANES   # cycles per 128-d dot = 4
CLOCK_HZ = 225e6
HBM_BPS = 460e9
HBM_EFF = 0.62      # effective HBM utilization (calibrated to Table III's
                    # 12.3 ms/token for LLaMA2-7B; typical for FPGA HBM AXI)
KV_BYTES_PER_ELT = 1    # KV cache stored INT8 (SFU quantize/cast, Fig. 5c)

# EXP_LAT / DIV_LAT calibrated once against Fig. 7b's three reported ratios
# (grid search; see calibrate()) — physically plausible FPGA latencies for a
# LUT-exp pipeline and a 32-bit fixed-point divider. SCORE_RW models the
# score-buffer write+readback of schedules that materialize scores.
EXP_LAT = 7
DIV_LAT = 38
SCORE_RW = 2
FILL = 8            # pipeline fill/drain


def swiftkv_cycles(n: int, d: int = D_HEAD) -> float:
    """Per-token pipelined single pass: dot dominates; compare/exp/update
    retire in its shadow (§III). One deferred normalization (Eq. 8)."""
    return FILL + n * DOT + DIV_LAT + d // LANES


def native_cycles(n: int, d: int = D_HEAD) -> float:
    """Conventional two-pass with score materialization, serialized stages:
    score pass (dot + score-buffer write, not overlapped), softmax stage
    (max scan, exp per score through the shared exp unit, sum, divide per
    score on the pipelined divider), PV pass (score readback + MAC)."""
    score = n * (2 * DOT + SCORE_RW)
    softmax = n + n * EXP_LAT + n + (n + DIV_LAT)
    pv = n * (2 * DOT + SCORE_RW)
    return score + softmax + pv


def flash_cycles(n: int, block: int, d: int = D_HEAD) -> float:
    """Blockwise on one hardware set: B pipelined dots per block, then a
    non-overlapped epilogue (the paper's "waiting for block"): block max
    scan, B exps through the shared exp unit, block score-buffer traffic,
    rescale of the running (Z, Y[d]) accumulator, and the per-block output
    rescale through the divider ([d] elements + divider latency)."""
    n_blocks = -(-n // block)
    per_block = (block * DOT + block + block * EXP_LAT + SCORE_RW * block
                 + 2 * (d // LANES) + d + DIV_LAT)
    return FILL + n_blocks * per_block + DIV_LAT + d // LANES


def streaming_cycles(n: int, d: int = D_HEAD) -> float:
    """Two-pass online softmax [15]: pass 1 = dots + running max/sum with
    the exp unit on the critical path (EXP_LAT > DOT); pass 2 = recompute
    exp + MAC into the output; one final divide."""
    pass1 = n * max(DOT, EXP_LAT)
    pass2 = n * max(DOT, EXP_LAT)
    return FILL + pass1 + pass2 + DIV_LAT + d // LANES


def speedups_at(n: int = 512) -> dict[str, float]:
    base = native_cycles(n)
    return {
        "native": 1.0,
        "flash8": base / flash_cycles(n, 8),
        "flash16": base / flash_cycles(n, 16),
        "flash32": base / flash_cycles(n, 32),
        "streaming": base / streaming_cycles(n),
        "swiftkv": base / swiftkv_cycles(n),
    }


def calibrate() -> dict:
    """Report model ratios vs the paper's Fig. 7b targets."""
    got = speedups_at(512)
    targets = {"flash32": 1.46, "streaming": 2.15, "swiftkv": 7.16}
    return {k: {"model": round(got[k], 2), "paper": v,
                "rel_err": round(abs(got[k] - v) / v, 3)}
            for k, v in targets.items()}


# ---------------------------------------------------------------------------
# Model-level decode latency (Fig. 8a, Table III)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class EdgeModel:
    name: str
    n_params: float          # weight count (decoder stack, excl. embeddings)
    d_model: int
    n_layers: int
    n_heads: int
    ctx: int
    vocab: int = 32000
    n_kv_heads: int | None = None   # MQA/GQA (ChatGLM2: 2)

    @property
    def weight_bytes(self) -> float:
        return self.n_params * 0.5   # W4: two params per byte

    @property
    def kv_frac(self) -> float:
        kv = self.n_kv_heads or self.n_heads
        return kv / self.n_heads


LLAMA2_7B = EdgeModel("llama2-7b", n_params=6.48e9, d_model=4096,
                      n_layers=32, n_heads=32, ctx=512)
CHATGLM_6B = EdgeModel("chatglm-6b", n_params=5.7e9, d_model=4096,
                       n_layers=28, n_heads=32, ctx=512, vocab=65024,
                       n_kv_heads=2)


def decode_latency_breakdown(m: EdgeModel, *, attention: str = "swiftkv",
                             flash_block: int = 32) -> dict:
    """Per-token decode latency split into module times (seconds).

    GEMV: the 32-processor array does a 4096-d dot/cycle (one output
    element/cycle, §IV-B) but weight *fetch* is the real bound: W4 weights
    stream from HBM once per token -> t = bytes/HBM. We take
    max(compute, HBM) per the dual bound. Attention: per-head cycles from
    the schedule model; 32 heads run on 32 processors in parallel, KV reads
    (2 * ctx * d_model * 2B fp16-equivalent... stored FXP/INT8 per §IV) also
    bound by HBM. SFU (norms/SiLU/rope): elementwise, d_model-wide vector
    ops, a few passes per layer."""
    # GEMV: compute cycles = one output element per cycle over all matmul
    # output dims per layer (q,k,v,o: 4*d^2; ffn: 3*d*2.7d) + lm head
    ffn_mult = 2.7          # llama-style gate/up/down
    out_elems = m.n_layers * (4 * m.d_model ** 2
                              + 3 * ffn_mult * m.d_model ** 2) / m.d_model
    gemv_compute = out_elems / CLOCK_HZ
    gemv_hbm = m.weight_bytes / (HBM_BPS * HBM_EFF)
    gemv = max(gemv_compute, gemv_hbm)

    # attention: 32 heads in parallel on 32 SKV processors
    sched = {"swiftkv": swiftkv_cycles,
             "native": native_cycles,
             "streaming": streaming_cycles,
             "flash": lambda n: flash_cycles(n, flash_block)}[attention]
    attn_cycles = sched(m.ctx) * m.n_layers          # heads parallel
    kv_bytes = (2 * m.ctx * m.d_model * m.n_layers * KV_BYTES_PER_ELT
                * m.kv_frac)
    attn = max(attn_cycles / CLOCK_HZ, kv_bytes / (HBM_BPS * HBM_EFF))

    # SFU: ~6 elementwise d_model-wide passes per layer at 32 lanes
    sfu = m.n_layers * 6 * (m.d_model / LANES) / CLOCK_HZ
    # lm head GEMV
    head = max(m.vocab * m.d_model * 0.5 / (HBM_BPS * HBM_EFF),
               m.vocab / CLOCK_HZ)
    total = gemv + attn + sfu + head
    return {"gemv_s": gemv, "attention_s": attn, "sfu_s": sfu,
            "lm_head_s": head, "total_s": total,
            "attention_share": attn / total,
            "tokens_per_s": 1.0 / total,
            "ms_per_token": total * 1e3}
