"""Render the §Roofline table from the dry-run sweep reports
(reports/dryrun/*.json). Single-pod cells only, per the deliverable; the
multi-pod passes prove lowering and are summarized separately."""
from __future__ import annotations

import json
from pathlib import Path

from repro.configs import ASSIGNED_ARCHS, SHAPES

COLS = ("t_compute_ms", "t_memory_ms", "t_collective_ms")


def load_reports(report_dir: str = "reports/dryrun") -> dict:
    out = {}
    for f in Path(report_dir).glob("*.json"):
        if f.name == "summary.json":
            continue
        try:
            out[f.stem] = json.loads(f.read_text())
        except Exception:
            pass
    return out


def cell_records(reports: dict, arch_id: str, shape: str):
    """(cost_record, memory_record): costs from the unrolled pass
    (trip-count-true), memory/fits from the scanned pass (the production
    program)."""
    unr = reports.get(f"{arch_id}__{shape}__sp__unroll")
    scan = reports.get(f"{arch_id}__{shape}__sp")
    cost = unr if (unr and unr.get("ok") and not unr.get("skipped")) else scan
    return cost, scan


def table(report_dir: str = "reports/dryrun") -> dict:
    reports = load_reports(report_dir)
    rows, skips, fails = [], [], []
    for arch in ASSIGNED_ARCHS:
        for shape in SHAPES:
            cost, scan = cell_records(reports, arch, shape)
            rep = cost or scan
            if rep is None:
                fails.append((arch, shape, "missing"))
                continue
            if rep.get("skipped"):
                skips.append((arch, shape, rep.get("reason", "")))
                continue
            if not rep.get("ok"):
                fails.append((arch, shape, rep.get("error", "?")[:80]))
                continue
            r = rep["roofline"]
            mem = (scan or rep).get("memory", rep.get("memory", {}))
            rows.append({
                "arch": arch, "shape": shape, "mode": rep.get("mode", "?"),
                "t_compute_ms": round(r["t_compute_ms"], 3),
                "t_memory_ms": round(r["t_memory_ms"], 3),
                "t_collective_ms": round(r["t_collective_ms"], 3),
                "dominant": r["dominant"],
                "useful_pct": round(100 * r["useful_frac"], 1),
                "roofline_pct": round(100 * r["roofline_frac"], 2),
                "mem_gb": round(mem.get("per_chip_gb", float("nan")), 2),
                "fits": mem.get("fits_16gb"),
            })
    return {"rows": rows, "skips": skips, "fails": fails}


def markdown(report_dir: str = "reports/dryrun") -> str:
    t = table(report_dir)
    lines = ["| arch | shape | t_comp ms | t_mem ms | t_coll ms | bound | "
             "useful% | roofline% | GB/chip | fits |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for r in t["rows"]:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_ms']} | "
            f"{r['t_memory_ms']} | {r['t_collective_ms']} | {r['dominant']} |"
            f" {r['useful_pct']} | {r['roofline_pct']} | {r['mem_gb']} | "
            f"{'y' if r['fits'] else 'N'} |")
    for a, s, reason in t["skips"]:
        lines.append(f"| {a} | {s} | — | — | — | skipped | — | — | — | — |")
    if t["fails"]:
        lines.append("")
        lines.append("Failures: " + "; ".join(
            f"{a}x{s}: {e}" for a, s, e in t["fails"]))
    return "\n".join(lines)


if __name__ == "__main__":
    print(markdown())
