"""MoE dispatch: capacity-scatter implementation vs the dense
loop-over-experts oracle (exact agreement under capacity head-room)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import moe


@pytest.mark.parametrize("e,top_k", [(4, 1), (8, 2), (16, 8)])
def test_moe_matches_dense_ref(e, top_k):
    d, dff, b, s = 16, 32, 2, 8
    p = moe.moe_init(jax.random.PRNGKey(0), d, dff, e)
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, d)) * 0.5
    # generous capacity: no token drops -> exact match with the dense oracle
    got, aux = moe.moe_apply(p, x, top_k=top_k, capacity=b * s * top_k)
    want = moe.moe_apply_dense_ref(p, x, top_k=top_k)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-4)
    assert float(aux) > 0


def test_moe_capacity_drops_are_partial_not_nan():
    d, dff, e = 16, 32, 4
    p = moe.moe_init(jax.random.PRNGKey(0), d, dff, e)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, d))
    got, _ = moe.moe_apply(p, x, top_k=2, capacity=2)  # brutal cap
    assert bool(jnp.all(jnp.isfinite(got)))
    # with all tokens hitting a 2-slot cap, most outputs are zero
    frac_zero = float(jnp.mean(jnp.all(got == 0, axis=-1)))
    assert frac_zero > 0.5


def test_moe_router_weights_normalized():
    d, dff, e = 8, 16, 4
    p = moe.moe_init(jax.random.PRNGKey(0), d, dff, e)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 4, d))
    # single expert with top_k = e reduces to a softmax-weighted mixture that
    # must equal the dense reference exactly
    got, _ = moe.moe_apply(p, x, top_k=e, capacity=64)
    want = moe.moe_apply_dense_ref(p, x, top_k=e)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_moe_aux_loss_uniform_router_is_one():
    """With a zero router the load-balance loss is exactly E·(1/E·1/E)·E=1."""
    d, dff, e = 8, 16, 4
    p = moe.moe_init(jax.random.PRNGKey(0), d, dff, e)
    p = dict(p, router=jnp.zeros_like(p["router"]))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, d))
    _, aux = moe.moe_apply(p, x, top_k=1, capacity=64)
    assert float(aux) == pytest.approx(1.0, rel=1e-5)
