"""Paper numerics: the Eq. 9-10 LUT exponential (max rel error 0.00586%),
the Q15.17 fixed-point datapath, and W4A8 quantization."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:          # optional dep: seeded explicit cases
    from _hypothesis_compat import given, settings, st

from repro.core import exp2_lut, fixedpoint, quantization
from repro.core.swiftkv import softmax_attention_reference

# ---------------------------------------------------------------------------
# LUT exponential (Eqs. 9-10)
# ---------------------------------------------------------------------------

PAPER_LUT_ERR = 5.86e-5  # "maximum relative error is 0.00586%"


def test_lut_exp_error_reproduces_paper_bound():
    err = exp2_lut.max_relative_error()
    # reproduce the figure (small slack for the grid / float32 eval)
    assert err < PAPER_LUT_ERR * 1.05, err
    assert err > PAPER_LUT_ERR * 0.5, f"suspiciously low: {err}"


@settings(max_examples=80, deadline=None)
@given(st.floats(min_value=-40.0, max_value=0.0, allow_nan=False))
def test_exp_lut_matches_exp(x):
    got = float(exp2_lut.exp_lut(jnp.float32(x)))
    want = float(np.exp(np.float32(x)))
    assert got == pytest.approx(want, rel=2e-4, abs=1e-12)


@settings(max_examples=80, deadline=None)
@given(st.floats(min_value=-0.999, max_value=0.0, allow_nan=False))
def test_fxp_lut_exp_bit_path(x):
    x_fxp = fixedpoint.to_fxp(np.float64(x))
    got = float(exp2_lut.exp_lut_fxp(x_fxp)) / (1 << exp2_lut.FRAC_BITS)
    assert got == pytest.approx(float(np.exp(x)), rel=3e-4, abs=2e-5)


def test_lut_table_values():
    vals, slopes = exp2_lut.make_lut()
    assert len(vals) == 32
    np.testing.assert_allclose(vals, 2.0 ** (-np.arange(32) / 32), rtol=1e-12)
    # slopes interpolate toward the next entry (LUT[32] = 0.5)
    np.testing.assert_allclose(vals + slopes,
                               2.0 ** (-np.arange(1, 33) / 32), rtol=1e-12)


# ---------------------------------------------------------------------------
# Q15.17 fixed point
# ---------------------------------------------------------------------------

ULP = 1.0 / (1 << 17)


@settings(max_examples=100, deadline=None)
@given(st.floats(min_value=-1e4, max_value=1e4, allow_nan=False))
def test_fxp_roundtrip(x):
    got = fixedpoint.from_fxp(fixedpoint.to_fxp(x))
    assert abs(got - x) <= ULP / 2 + 1e-12


@settings(max_examples=100, deadline=None)
@given(st.floats(min_value=-100, max_value=100),
       st.floats(min_value=-100, max_value=100))
def test_fxp_mul(a, b):
    got = fixedpoint.from_fxp(
        fixedpoint.fxp_mul(fixedpoint.to_fxp(a), fixedpoint.to_fxp(b)))
    assert got == pytest.approx(a * b, abs=(abs(a) + abs(b) + 1) * ULP)


@settings(max_examples=100, deadline=None)
@given(st.floats(min_value=-100, max_value=100),
       st.floats(min_value=0.01, max_value=100))
def test_fxp_div(a, b):
    got = fixedpoint.from_fxp(
        fixedpoint.fxp_div(fixedpoint.to_fxp(a), fixedpoint.to_fxp(b)))
    # compare against the exact quotient of the *quantized* operands — the
    # divider itself is round-to-nearest; input quantization of b dominates
    aq = fixedpoint.from_fxp(fixedpoint.to_fxp(a))
    bq = fixedpoint.from_fxp(fixedpoint.to_fxp(b))
    assert got == pytest.approx(aq / bq, abs=2 * ULP)


def test_fxp32_attention_precision_claim():
    """§III: FXP32 attention 'precision better than 1e-5'. We measure both
    max and mean absolute error of the full Q15.17 datapath (scores, LUT exp,
    running state, deferred divide) vs the f32 two-pass oracle."""
    rng = np.random.default_rng(0)
    errs = []
    for trial in range(5):
        d, s = 64, 128
        q = rng.standard_normal(d)
        k = rng.standard_normal((s, d))
        v = rng.standard_normal((s, d))
        got = fixedpoint.swiftkv_attention_fxp(q, k, v)
        want = np.asarray(softmax_attention_reference(
            jnp.asarray(q, jnp.float32), jnp.asarray(k, jnp.float32),
            jnp.asarray(v, jnp.float32)))
        errs.append(np.abs(got - want))
    errs = np.concatenate(errs)
    assert errs.mean() < 1e-5, errs.mean()     # paper's claim, on average
    assert errs.max() < 4 * ULP                # within a few fixed-point ulps


# ---------------------------------------------------------------------------
# W4A8 quantization
# ---------------------------------------------------------------------------

def test_w4_pack_unpack_roundtrip():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((256, 32)), jnp.float32)
    qw = quantization.quantize_w4(w)
    assert qw.packed.shape == (256, 16) and qw.packed.dtype == jnp.uint8
    assert qw.scale.shape == (256 // quantization.GROUP, 32)
    unpacked = quantization.unpack_w4(qw.packed)
    assert unpacked.shape == (256, 32)
    assert int(jnp.min(unpacked)) >= -8 and int(jnp.max(unpacked)) <= 7
    # dequantized weight within half a quant step per element (per group) —
    # except entries saturated by the MSE-optimal clip (error = |w| - 7*step)
    deq = quantization.dequantize_w4(qw)
    step = np.repeat(np.asarray(qw.scale), quantization.GROUP, axis=0)
    err = np.abs(np.asarray(deq - w))
    bound = np.maximum(step * 0.5, np.abs(np.asarray(w)) - 7.0 * step)
    assert np.all(err <= bound + 1e-7)


@settings(max_examples=40, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_w4_nibble_packing_exact(seed):
    rng = np.random.default_rng(seed)
    q = rng.integers(-8, 8, size=(8, 10)).astype(np.int8)
    lo = (q[:, 0::2].astype(np.uint8) & 0xF)
    hi = (q[:, 1::2].astype(np.uint8) & 0xF) << 4
    packed = jnp.asarray(lo | hi, jnp.uint8)
    out = np.asarray(quantization.unpack_w4(packed))
    np.testing.assert_array_equal(out, q)


def test_a8_quantization_error():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((4, 256)), jnp.float32)
    xq, xs = quantization.quantize_a8(x)
    back = xq.astype(jnp.float32) * xs
    assert float(jnp.max(jnp.abs(back - x))) <= float(jnp.max(xs)) * 0.51


def test_w4a8_matmul_close_to_float():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((4, 512)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((512, 128)) * 0.02, jnp.float32)
    qw = quantization.quantize_w4(w)
    got = quantization.w4a8_matmul_ref(x, qw)
    want = x @ w
    rel = float(jnp.linalg.norm(got - want) / jnp.linalg.norm(want))
    # RTN int4 on gaussian weights floors at ~10.5% relative (MSE-optimal
    # clip); real checkpoints do better, random inits don't.
    assert rel < 0.13, rel
