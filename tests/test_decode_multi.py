"""Multi-tick decode blocks (``TransformerLM.decode_multi`` + the engine's
adaptive tick horizon): seeded temperature>0 streams must be
*tick-horizon-invariant* (sampler keys are request-intrinsic — (seed,
serial, token index) — so the draw for token i cannot depend on how ticks
were blocked); on-device EOS/budget retirement must match the host's
replay; and the dispatch accounting must actually show the round-trip
collapse. The per-family greedy-equivalence sweep at decode_ticks 1 and 8
lives in the shared harness of ``test_serving_conformance.py``."""
from __future__ import annotations

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.api import build_model
from repro.serving import ContinuousBatchingEngine, Request, poisson_trace

jax.config.update("jax_platform_name", "cpu")

TICK_HORIZONS = (1, 4, 8)


def _build(arch):
    cfg = get_config(arch, reduced=True)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def dense_model():
    return _build("llama2-7b")


def test_sampled_stream_invariant_across_tick_horizons(dense_model):
    """Seeded temperature>0 replay: the same (seed, trace) draws the same
    tokens at decode_ticks 1, 4, and 8. This is true *by construction* —
    the Gumbel key for a request's token i is fold_in(fold_in(seed_key,
    admission serial), i), none of which depends on the tick horizon — and
    this test proves the construction survives the scan."""
    cfg, model, params = dense_model
    trace = poisson_trace(n_requests=5, vocab_size=cfg.vocab_size,
                          prompt_len=(3, 18), max_new=(4, 10), seed=3)

    def run(ticks, seed=7):
        eng = ContinuousBatchingEngine(model, params, n_slots=2, max_len=64,
                                       chunk=8, temperature=0.8, seed=seed,
                                       decode_ticks=ticks)
        eng.warmup()
        rep = eng.run(list(trace))
        return {r["rid"]: r["tokens"] for r in rep["requests"]}

    streams = {t: run(t) for t in TICK_HORIZONS}
    assert streams[1] == streams[4] == streams[8]
    assert run(4, seed=9) != streams[4]      # a different seed differs


def test_on_device_eos_retires_mid_block_and_backfills(dense_model):
    """A row whose sampled token hits eos_id mid-block flips inactive on
    device (remaining ticks park its writes); the host replay retires it
    from the token block alone, and a queued request backfills the slot."""
    cfg, model, params = dense_model
    prompt = np.arange(5, dtype=np.int32)
    probe = ContinuousBatchingEngine(model, params, n_slots=1, max_len=64,
                                     chunk=8)
    free = probe.run([Request(prompt=prompt, max_new_tokens=8, rid="probe")])
    toks = free["requests"][0]["tokens"]
    eos = toks[1]

    eng = ContinuousBatchingEngine(model, params, n_slots=1, max_len=64,
                                   chunk=8, eos_id=eos, decode_ticks=8)
    report = eng.run([Request(prompt=prompt, max_new_tokens=8, rid="a"),
                      Request(prompt=prompt + 1, max_new_tokens=3, rid="b")])
    by_rid = {r["rid"]: r for r in report["requests"]}
    assert by_rid["a"]["tokens"] == toks[:2]    # EOS emitted, then retired
    assert by_rid["a"]["finish_reason"] == "eos"
    assert by_rid["b"]["n_tokens"] >= 1
    assert eng.pool.n_free == 1


def test_dispatch_accounting_shows_collapse(dense_model):
    """The optimization must be measurable: at decode_ticks=8 the engine
    launches strictly fewer decode programs than it executes ticks, and
    dispatches_per_token drops vs the single-tick engine on the same
    trace."""
    cfg, model, params = dense_model
    trace = poisson_trace(n_requests=4, vocab_size=cfg.vocab_size,
                          prompt_len=(3, 10), max_new=(8, 16), seed=2)

    def agg(ticks):
        eng = ContinuousBatchingEngine(model, params, n_slots=2, max_len=64,
                                       chunk=8, decode_ticks=ticks)
        return eng.run(list(trace))["aggregate"]

    one, eight = agg(1), agg(8)
    assert one["decode_dispatches"] == one["decode_steps"]
    assert eight["decode_dispatches"] < eight["decode_steps"]
    assert eight["dispatches_per_token"] < one["dispatches_per_token"]
    assert eight["host_syncs"] < one["host_syncs"]
    assert eight["generated_tokens"] == one["generated_tokens"]
    # block-granularity honesty: the multi-tick report carries the note
    assert "itl_note" in eight and "itl_effective_ms" in eight
    assert "itl_note" not in one


def test_decode_multi_rejects_bad_ticks(dense_model):
    cfg, model, params = dense_model
    with pytest.raises(ValueError):
        ContinuousBatchingEngine(model, params, n_slots=2, max_len=64,
                                 chunk=8, decode_ticks=0)


def test_batched_prefill_single_dispatch(dense_model):
    """All mid-prefill slots advance in one prefill_chunks_batched launch
    per engine step: with 4 multi-chunk prompts and 4 slots the engine must
    launch far fewer prefill programs than chunk advances."""
    cfg, model, params = dense_model
    trace = [Request(prompt=np.arange(24, dtype=np.int32) + i,
                     max_new_tokens=3, rid=i) for i in range(4)]
    eng = ContinuousBatchingEngine(model, params, n_slots=4, max_len=64,
                                   chunk=8, decode_ticks=4)
    agg = eng.run(trace)["aggregate"]
    assert agg["prefill_chunks"] == 12          # 4 prompts x 3 chunks
    assert agg["prefill_dispatches"] == 3       # one per step, not per slot
