"""Distribution layer tests. Sharding rules are pure functions (testable on
one device); the shard_map sequence-parallel decode and the multi-device
plumbing run in a subprocess with a forced 8-device world."""
from __future__ import annotations

import json
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.distributed.sharding import fixup_divisibility
from repro.distributed import roofline


# ---------------------------------------------------------------------------
# pure-function pieces
# ---------------------------------------------------------------------------

class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape


def test_fixup_drops_nondivisible():
    mesh = _FakeMesh({"data": 16, "model": 16})
    assert fixup_divisibility(P("model", None), (503, 64), mesh) == P(None, None)
    assert fixup_divisibility(P("model", None), (512, 64), mesh) == P("model", None)
    assert fixup_divisibility(P(("data", "model"), None), (256, 8), mesh) == \
        P(("data", "model"), None)
    assert fixup_divisibility(P(("data", "model"), None), (128, 8), mesh) == \
        P(None, None)
    # trailing dims beyond the spec stay unsharded
    assert fixup_divisibility(P("data"), (32, 7, 9), mesh) == P("data", None, None)


def test_roofline_collective_parse():
    hlo = textwrap.dedent("""\
        %p0 = bf16[8,4096]{1,0} parameter(0)
        %ag = bf16[128,4096]{1,0} all-gather(%p0), replica_groups=[16,16]<=[256], dimensions={0}
        %ar = f32[1024]{0} all-reduce(%red), replica_groups=[2,128]<=[256], to_apply=%sum
        %red = f32[1024]{0} add(%x, %y)
        %cp = bf16[8,4096]{1,0} collective-permute(%p0), source_target_pairs={{0,1}}
    """)
    stats = roofline.parse_collectives(hlo)
    assert stats.op_counts == {"all-gather": 1, "all-reduce": 1,
                               "collective-permute": 1}
    ag_out = 128 * 4096 * 2
    ar_b = 1024 * 4
    cp_b = 8 * 4096 * 2
    want = (15 / 16) * ag_out + 2 * (127 / 128) * ar_b + cp_b
    assert stats.ici_bytes == pytest.approx(want)


def test_roofline_terms_and_dominance():
    rep = roofline.RooflineReport(
        arch="x", shape="y", mesh="16x16", n_chips=256,
        hlo_flops=197e12 * 0.001,      # 1 ms compute
        hlo_bytes=819e9 * 0.002,       # 2 ms memory
        collective_op_bytes=0,
        collective_ici_bytes=50e9 * 0.0005,   # 0.5 ms collective
        bytes_per_chip=1e9, model_flops=197e12 * 0.001 * 256 * 0.5).finalize()
    assert rep.dominant == "memory"
    assert rep.t_bound == pytest.approx(0.002)
    assert rep.useful_flops_fraction == pytest.approx(0.5)
    assert rep.roofline_fraction == pytest.approx(0.25)


def test_model_flops_moe_counts_active_only():
    dense = get_config("qwen3_8b")
    moe = get_config("olmoe_1b_7b")
    total, active = roofline.count_params(moe)
    assert active < total * 0.35                     # 8 of 64 experts
    t2, a2 = roofline.count_params(dense)
    assert t2 == a2
    # qwen3-8b should count ~8B params
    assert 7e9 < t2 < 9.5e9, t2


def test_count_params_vlm_includes_cross_layers():
    cfg = get_config("llama32_vision_90b")
    total, _ = roofline.count_params(cfg)
    assert 80e9 < total < 110e9, total


# ---------------------------------------------------------------------------
# multi-device (subprocess with 8 forced host devices)
# ---------------------------------------------------------------------------

_SP_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import warnings; warnings.filterwarnings("ignore")
import jax, jax.numpy as jnp, numpy as np, json
from repro.distributed.sp_attention import decode_attention_sp
from repro.kernels.swiftkv_decode.ref import swiftkv_decode_ref

mesh = jax.make_mesh((4, 2), ("data", "model"))
rng = np.random.default_rng(0)
b, hq, hkv, s, d = 2, 4, 2, 256, 32
q = jnp.asarray(rng.standard_normal((b, hq, d)), jnp.float32)
k = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
v = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
errs = {}
for name, lens, win in [("full", [256, 256], None), ("ragged", [200, 77], None),
                        ("window", [256, 200], 64)]:
    lengths = jnp.asarray(lens, jnp.int32)
    out = decode_attention_sp(q, k, v, lengths, mesh=mesh, seq_axes="model",
                              window=win)
    want = swiftkv_decode_ref(q, k, v, lengths, window=win)
    errs[name] = float(jnp.max(jnp.abs(out - want)))
print(json.dumps(errs))
"""


@pytest.mark.slow
def test_sequence_parallel_decode_multidevice():
    proc = subprocess.run([sys.executable, "-c", _SP_SCRIPT],
                          capture_output=True, text=True, timeout=300,
                          env={**__import__("os").environ,
                               "PYTHONPATH": "src"},
                          cwd=Path(__file__).resolve().parent.parent)
    assert proc.returncode == 0, proc.stderr[-2000:]
    errs = json.loads(proc.stdout.strip().splitlines()[-1])
    for name, e in errs.items():
        assert e < 5e-6, (name, e)


_DRYRUN_SCRIPT_OK = """\
import json, sys
from repro.launch.dryrun import run_cell
rep = run_cell(sys.argv[1], sys.argv[2], multi_pod=(sys.argv[3] == "mp"),
               reduced=True)
print(json.dumps({"ok": rep["ok"], "err": rep.get("error", "")}))
"""


@pytest.mark.slow
@pytest.mark.parametrize("arch,shape,mesh", [
    ("qwen3-8b", "decode_32k", "sp"),
    ("whisper-small", "train_4k", "mp"),
])
def test_dryrun_machinery_reduced(arch, shape, mesh):
    """The dry-run lowers + compiles a reduced cell on both mesh shapes
    (full-size cells run via the out-of-band report sweep)."""
    proc = subprocess.run(
        [sys.executable, "-c", _DRYRUN_SCRIPT_OK, arch, shape, mesh],
        capture_output=True, text=True, timeout=1200,
        env={**__import__("os").environ, "PYTHONPATH": "src"},
        cwd=Path(__file__).resolve().parent.parent)
    assert proc.returncode == 0, proc.stderr[-2000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["ok"], out["err"]
