"""Property suite for the W4A8 serving-path quantizers (paper §IV-B).

These are the *measured-tolerance* contracts the +w4a8 serving configs rest
on: the conformance layer (test_serving_conformance.py) gates the engines on
agreement/parity thresholds, and this file pins the component-level error
ceilings that make those thresholds meaningful — int4 group-128 weight
round-trip, the MSE clip search never losing to plain min-max, nibble
pack/unpack bijection, and the int8 KV scale law (constant rows exact,
zero rows stored with scale 0 so a released slot is all-zeros).

Also holds the ``init_cache`` dtype/bytes unit test (the latent fp32
assumption fixed alongside the +w4a8 axis): reported cache bytes for
fp32, ring, and int8 caches, and the ``kv_dtype`` override.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:          # optional dep: seeded explicit cases
    from _hypothesis_compat import given, settings, st

from repro.core.quantization import (GROUP, QuantizedLinear, dequantize_kv,
                                     dequantize_w4, quantize_a8, quantize_kv,
                                     quantize_w4, unpack_w4, w4a8_matmul_ref)

# ---------------------------------------------------------------------------
# int4 weight round-trip
# ---------------------------------------------------------------------------

# RTN int4 with group-128 scales and MSE clip search sits at ~10.5-11.6%
# relative error on gaussian weights (the RTN-int4 floor — 16 levels over a
# bell curve; see quantize_w4's docstring) essentially independent of shape.
# 12.5% is the measured ceiling with margin; min-max-only scaling sits ~12%,
# which the clip-search-dominance test below keeps strictly at or above us.
W4_GROUP128_CEILING = 0.125


def _rel_err(got, want):
    return float(np.linalg.norm(got - want) / (np.linalg.norm(want) + 1e-12))


@settings(max_examples=12, deadline=None)
@given(st.integers(min_value=2, max_value=8),
       st.integers(min_value=1, max_value=64),
       st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_w4_roundtrip_ceiling_group128(k_groups, n_half, seed):
    """Dequant(quantize_w4(w)) stays within the group-128 error ceiling on
    gaussian weights, for any K that's a multiple of GROUP and any even N."""
    k, n = k_groups * GROUP, 2 * n_half
    w = np.random.default_rng(seed).normal(size=(k, n)).astype(np.float32)
    qw = quantize_w4(jnp.asarray(w))
    back = np.asarray(dequantize_w4(qw))
    assert back.shape == (k, n)
    assert _rel_err(back, w) < W4_GROUP128_CEILING


def test_w4_roundtrip_partial_group_pads():
    """K not a multiple of GROUP: the trailing partial group is padded for
    scale computation but the round trip returns the original K rows."""
    w = np.random.default_rng(0).normal(size=(GROUP + 37, 16)).astype(np.float32)
    qw = quantize_w4(jnp.asarray(w))
    back = np.asarray(dequantize_w4(qw))
    assert back.shape == w.shape
    # partial-group scales see zero-padding, still bounded well below junk
    assert _rel_err(back, w) < 2 * W4_GROUP128_CEILING


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=2 ** 31 - 1),
       st.floats(min_value=0.1, max_value=4.0))
def test_clip_search_never_worse_than_minmax(seed, sigma):
    """The per-group MSE clip search must dominate plain min-max scaling
    (clip factor 1.0 is one of the candidates, so >= is structural — this
    pins that the search actually compares per (group, out-channel))."""
    rng = np.random.default_rng(seed)
    w = (rng.normal(size=(2 * GROUP, 32)) * sigma).astype(np.float32)
    qw = quantize_w4(jnp.asarray(w))
    got = _rel_err(np.asarray(dequantize_w4(qw)), w)

    # plain min-max (clip 1.0) reference, same grouping
    wg = w.reshape(-1, GROUP, w.shape[1])
    amax = np.abs(wg).max(axis=1)
    s = np.where(amax > 0, amax / 7.0, 1.0)
    q = np.clip(np.round(wg / s[:, None, :]), -8, 7)
    minmax = _rel_err((q * s[:, None, :]).reshape(w.shape), w)
    assert got <= minmax + 1e-7, (got, minmax)


def test_w4_pack_unpack_bijection():
    """Every int4 value in [-8, 7] survives the nibble pack/unpack in both
    lane positions (lo and hi)."""
    vals = np.arange(-8, 8, dtype=np.int8)
    q = np.stack(np.meshgrid(vals, vals, indexing="ij"), -1).reshape(1, -1)
    lo = q[:, 0::2].astype(np.uint8) & 0xF
    hi = (q[:, 1::2].astype(np.uint8) & 0xF) << 4
    packed = jnp.asarray(lo | hi)
    assert np.array_equal(np.asarray(unpack_w4(packed)), q)


def test_w4_rejects_odd_output_dim():
    with pytest.raises(AssertionError):
        quantize_w4(jnp.zeros((GROUP, 7)))


def test_w4_zero_weight_group_is_stable():
    """An all-zero group quantizes to zeros with the safe scale 1.0 — no
    NaN/inf leaks into the scales."""
    w = np.zeros((GROUP, 4), np.float32)
    qw = quantize_w4(jnp.asarray(w))
    assert np.all(np.isfinite(np.asarray(qw.scale)))
    assert np.array_equal(np.asarray(dequantize_w4(qw)), w)


# ---------------------------------------------------------------------------
# int8 activations + reference matmul
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_a8_roundtrip(seed):
    x = np.random.default_rng(seed).normal(size=(3, 257)).astype(np.float32)
    q, s = quantize_a8(jnp.asarray(x))
    back = np.asarray(q, np.float32) * np.asarray(s)
    assert _rel_err(back, x) < 0.01         # int8: ~0.4% on gaussians


def test_a8_zero_row_safe_scale():
    q, s = quantize_a8(jnp.zeros((2, 64)))
    assert np.array_equal(np.asarray(q), np.zeros((2, 64)))
    assert np.all(np.asarray(s) == 1.0)     # activations: safe scale, not 0


def test_w4a8_matmul_ref_matches_dequant_oracle():
    """The int32-accumulate / group-rescale reference equals quantize-both
    -then-float-matmul exactly (same arithmetic, different order) — this is
    the semantics the Pallas kernel is pinned against in test_kernels_gemv."""
    rng = np.random.default_rng(3)
    x = rng.normal(size=(5, 2 * GROUP)).astype(np.float32)
    w = rng.normal(size=(2 * GROUP, 48)).astype(np.float32)
    qw = quantize_w4(jnp.asarray(w))
    got = np.asarray(w4a8_matmul_ref(jnp.asarray(x), qw))
    xq, xs = quantize_a8(jnp.asarray(x))
    oracle = (np.asarray(xq, np.float32) * np.asarray(xs)) \
        @ np.asarray(dequantize_w4(qw))
    np.testing.assert_allclose(got, oracle, rtol=1e-5, atol=1e-4)


# ---------------------------------------------------------------------------
# int8 KV cache scale law
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.floats(min_value=-100.0, max_value=100.0),
       st.integers(min_value=1, max_value=256))
def test_kv_constant_row_roundtrips_exactly(c, dh):
    """c * ones stores scale |c|/127 and q = ±127 → dequant returns c
    bit-exactly (the quantize_kv docstring's exactness property)."""
    x = jnp.full((dh,), np.float32(c))
    q, s = quantize_kv(x)
    back = np.asarray(dequantize_kv(q, s))
    if c == 0.0:
        assert float(s) == 0.0
        assert np.array_equal(back, np.zeros(dh))
    else:
        assert float(s) == np.float32(abs(np.float32(c))) / np.float32(127.0)
        np.testing.assert_array_equal(back, np.full(dh, np.float32(c)))


def test_kv_zero_row_stores_scale_zero():
    """Released-slot invariant: zero rows → scale 0 (not the safe 1.0), so
    zeroing rows AND scale planes leaves no stale device state behind."""
    q, s = quantize_kv(jnp.zeros((4, 2, 16)))
    assert np.array_equal(np.asarray(q), np.zeros((4, 2, 16)))
    assert np.array_equal(np.asarray(s), np.zeros((4, 2)))


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=0, max_value=2 ** 31 - 1))
def test_kv_gaussian_roundtrip(seed):
    x = np.random.default_rng(seed).normal(size=(6, 64)).astype(np.float32)
    q, s = quantize_kv(jnp.asarray(x))
    assert _rel_err(np.asarray(dequantize_kv(q, s)), x) < 0.01


# ---------------------------------------------------------------------------
# quantize_params walk
# ---------------------------------------------------------------------------

def test_quantize_params_replaces_projections_only():
    from repro.models.quantized import quantize_params
    params = {"embed": jnp.ones((16, 8)),
              "blocks": {"attn": {"wq": jnp.ones((8, 8)),
                                  "norm": jnp.ones((8,))},
                         "mlp": {"up": jnp.ones((4, 8, 8))}}}
    out = quantize_params(params)
    assert "embed" in out and out["embed"].shape == (16, 8)
    attn = out["blocks"]["attn"]
    assert "wq" not in attn and "wq__qp" in attn and "wq__qs" in attn
    assert "norm" in attn
    mlp = out["blocks"]["mlp"]
    assert "up__qp" in mlp and mlp["up__qp"].shape == (4, 8, 4)  # stacked


def test_quantize_params_is_deterministic():
    """No RNG anywhere in the walk — the seeded-replay conformance tests
    rely on quantize-at-engine-construction being bit-stable."""
    from repro.models.quantized import quantize_params
    w = jnp.asarray(np.random.default_rng(0).normal(size=(GROUP, 16))
                    .astype(np.float32))
    a = quantize_params({"wq": w})
    b = quantize_params({"wq": w})
    assert np.array_equal(np.asarray(a["wq__qp"]), np.asarray(b["wq__qp"]))
    assert np.array_equal(np.asarray(a["wq__qs"]), np.asarray(b["wq__qs"]))


# ---------------------------------------------------------------------------
# init_cache dtype / reported bytes (the latent fp32 assumption, fixed)
# ---------------------------------------------------------------------------

def _cache_bytes(cache, keys):
    return sum(int(np.prod(cache[k].shape)) * cache[k].dtype.itemsize
               for k in keys if k in cache)


def _build(arch):
    from repro.configs import get_config
    from repro.models.api import build_model
    cfg = get_config(arch, reduced=True)
    return cfg, build_model(cfg)


@pytest.mark.parametrize("arch", ["qwen3_8b", "h2o_danube_1p8b+ring"])
def test_init_cache_fp32_bytes(arch):
    cfg, model = _build(arch)
    cache = model.init_cache(2, 128)
    assert cache["k"].dtype == jnp.dtype(cfg.compute_dtype)
    assert "k_scale" not in cache
    want = 2 * np.prod(cache["k"].shape) * cache["k"].dtype.itemsize
    assert _cache_bytes(cache, ("k", "v", "k_scale", "v_scale")) == want


def test_init_cache_int8_default_for_w4a8():
    cfg, model = _build("qwen3_8b+w4a8")
    assert cfg.w4a8_serve
    cache = model.init_cache(2, 128)
    assert cache["k"].dtype == jnp.int8 and cache["v"].dtype == jnp.int8
    assert cache["k_scale"].dtype == jnp.bfloat16
    # one scale per (layer, slot, kv-head, position) — position LAST (the
    # blocked axis), vs the rows' [L, B, S, Hkv, Dh] layout
    l, b, s, hkv, _ = cache["k"].shape
    assert cache["k_scale"].shape == (l, b, hkv, s)

    base_cfg, base_model = _build("qwen3_8b")
    fp = base_model.init_cache(2, 128)
    keys = ("k", "v", "k_scale", "v_scale")
    ratio = _cache_bytes(cache, keys) / _cache_bytes(fp, keys)
    # int8 rows + bf16 scale per Dh-row: 1/4 + 2/(4*Dh) of fp32 — stays
    # under the 0.3x budget even at the reduced configs' Dh = 16
    dh = cache["k"].shape[-1]
    assert ratio == pytest.approx(0.25 + 0.5 / dh, rel=1e-6)
    assert ratio <= 0.3


def test_init_cache_kv_dtype_override():
    """kv_dtype overrides the config-derived default in both directions:
    int8 on a base config allocates scale planes; an explicit float dtype
    on a +w4a8 config suppresses them."""
    _, base = _build("qwen3_8b")
    c8 = base.init_cache(1, 64, kv_dtype=jnp.int8)
    assert c8["k"].dtype == jnp.int8 and "k_scale" in c8

    _, quant = _build("qwen3_8b+w4a8")
    cf = quant.init_cache(1, 64, kv_dtype=jnp.float32)
    assert cf["k"].dtype == jnp.float32 and "k_scale" not in cf


def test_init_cache_int8_ring_shapes():
    """+ring+w4a8: the ring cache stores int8 rows over R ring rows and the
    scale planes tile the same R axis (one scale per ring row per head)."""
    cfg, model = _build("h2o_danube_1p8b+ring+w4a8")
    cache = model.init_cache(2, 256)
    l, b, rows, hkv, _ = cache["k"].shape
    assert cache["k"].dtype == jnp.int8
    assert cache["k_scale"].shape == (l, b, hkv, rows)
    assert rows < 256      # ring: R = window-derived rows, not max_len
