"""Seeded explicit-case fallback for the optional ``hypothesis`` dependency.

Test modules try the real library first and fall back to this shim, so the
tier-1 suite *collects and runs* on images that don't ship hypothesis. The
shim mirrors the tiny decorator surface these tests use (``given`` /
``settings`` / ``strategies.floats|integers|lists``): each ``@given`` test
runs over the strategies' boundary values plus a fixed number of seeded
random draws — deterministic explicit cases, not adaptive search.
"""
from __future__ import annotations

import numpy as np

_DEFAULT_EXAMPLES = 20
_MAX_EXAMPLES_CAP = 30   # explicit cases: keep the suite fast


class _Strategy:
    def __init__(self, draw, boundaries=()):
        self._draw = draw
        self.boundaries = list(boundaries)

    def example(self, rng):
        return self._draw(rng)


class _Strategies:
    @staticmethod
    def floats(min_value=-1e6, max_value=1e6, allow_nan=False,
               allow_infinity=False, width=64):
        lo, hi = float(min_value), float(max_value)
        bounds = [lo, hi]
        if lo <= 0.0 <= hi:
            bounds.append(0.0)
        return _Strategy(lambda rng: float(rng.uniform(lo, hi)), bounds)

    @staticmethod
    def integers(min_value, max_value):
        lo, hi = int(min_value), int(max_value)
        return _Strategy(lambda rng: int(rng.integers(lo, hi,
                                                      endpoint=True)),
                         [lo, hi])

    @staticmethod
    def lists(elements, min_size=0, max_size=None):
        hi = min_size + 4 if max_size is None else max_size

        def draw(rng):
            n = int(rng.integers(min_size, hi, endpoint=True))
            return [elements.example(rng) for _ in range(n)]
        return _Strategy(draw)


st = _Strategies()


def settings(max_examples=_DEFAULT_EXAMPLES, deadline=None, **_kw):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco


def given(*strategies):
    def deco(fn):
        def run():
            n = min(getattr(run, "_max_examples",
                            getattr(fn, "_max_examples", _DEFAULT_EXAMPLES)),
                    _MAX_EXAMPLES_CAP)
            rng = np.random.default_rng(0)
            cases = []
            # aligned boundary tuples (all-lo / all-hi / zeros), gaps drawn
            width = max((len(s.boundaries) for s in strategies), default=0)
            for i in range(width):
                cases.append(tuple(
                    s.boundaries[i] if i < len(s.boundaries)
                    else s.example(rng) for s in strategies))
            while len(cases) < n:
                cases.append(tuple(s.example(rng) for s in strategies))
            for case in cases[:n]:
                fn(*case)

        # no functools.wraps: pytest must see the zero-arg signature, not
        # the wrapped one (it would try to resolve ``x`` as a fixture)
        run.__name__ = fn.__name__
        run.__doc__ = fn.__doc__
        run.__module__ = fn.__module__
        run._max_examples = getattr(fn, "_max_examples", _DEFAULT_EXAMPLES)
        return run
    return deco
