"""Mamba (hybrid branch) and RWKV6: the full-sequence (training) path and the
O(1)-state decode path must produce identical outputs step by step — this is
the property that lets ssm/hybrid archs run the 500k-context decode shape."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import mamba, rwkv6


def test_mamba_forward_vs_decode_steps():
    d, s, b = 16, 10, 2
    p = mamba.mamba_init(jax.random.PRNGKey(0), d, state=4, conv=4, expand=2)
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, d)) * 0.5

    full = mamba.mamba_forward(p, x)

    st = mamba.mamba_init_state(p, b)
    outs = []
    for t in range(s):
        y, st = mamba.mamba_decode_step(p, x[:, t, :], st)
        outs.append(y)
    stepped = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(stepped),
                               atol=1e-4, rtol=1e-3)


def test_mamba_forward_return_state_matches_decode_state():
    d, s, b = 16, 6, 1
    p = mamba.mamba_init(jax.random.PRNGKey(0), d, state=4)
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, d)) * 0.5
    _, st_full = mamba.mamba_forward(p, x, return_state=True)
    st = mamba.mamba_init_state(p, b)
    for t in range(s):
        _, st = mamba.mamba_decode_step(p, x[:, t, :], st)
    np.testing.assert_allclose(np.asarray(st_full.ssm), np.asarray(st.ssm),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(st_full.conv), np.asarray(st.conv),
                               atol=1e-5)


def test_rwkv_time_mix_forward_vs_steps():
    d, s, b, hd = 32, 8, 2, 16
    p = rwkv6.rwkv_layer_init(jax.random.PRNGKey(0), d, 64, hd)
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, d)) * 0.5
    h = d // hd
    st0 = rwkv6.RWKVLayerState(
        x_prev_att=jnp.zeros((b, d)), x_prev_ffn=jnp.zeros((b, d)),
        wkv=jnp.zeros((b, h, hd, hd), jnp.float32))

    full, st_full = rwkv6.rwkv_time_mix(p, x, st0, hd)

    st = st0
    outs = []
    for t in range(s):
        y, st = rwkv6.rwkv_time_mix_step(p, x[:, t, :], st, hd)
        outs.append(y)
    stepped = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(stepped),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(st_full.wkv), np.asarray(st.wkv),
                               atol=1e-4, rtol=1e-3)


def test_rwkv_channel_mix_forward_vs_steps():
    d, s, b = 32, 8, 2
    p = rwkv6.rwkv_layer_init(jax.random.PRNGKey(0), d, 64, 16)
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, d)) * 0.5
    st0 = rwkv6.RWKVLayerState(
        x_prev_att=jnp.zeros((b, d)), x_prev_ffn=jnp.zeros((b, d)),
        wkv=jnp.zeros((b, 2, 16, 16), jnp.float32))
    full, _ = rwkv6.rwkv_channel_mix(p, x, st0)
    st = st0
    outs = []
    for t in range(s):
        y, st = rwkv6.rwkv_channel_mix_step(p, x[:, t, :], st)
        outs.append(y)
    np.testing.assert_allclose(np.asarray(full),
                               np.asarray(jnp.stack(outs, 1)),
                               atol=1e-4, rtol=1e-3)


def test_rwkv_decay_in_unit_interval():
    d = 32
    p = rwkv6.rwkv_layer_init(jax.random.PRNGKey(0), d, 64, 16)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, d)) * 2
    w = rwkv6._decay(p, x)
    assert float(w.min()) > 0.0 and float(w.max()) < 1.0
