"""Docs build/link check: every markdown link and anchor in README.md and
docs/*.md must resolve — a renamed file or retitled section breaks CI here,
not silently in a reader's browser. Kept dependency-free (no docs
toolchain in the image): links are extracted with a regex and anchors are
checked against GitHub-style heading slugs.
"""
from __future__ import annotations

import re
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]
DOCS = [ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))]

_LINK = re.compile(r"(?<!\!)\[[^\]]*\]\(([^)\s]+)\)")
_HEADING = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
_CODE_FENCE = re.compile(r"```.*?```", re.DOTALL)


def _slug(heading: str) -> str:
    """GitHub anchor slug: lowercase, drop punctuation, spaces -> hyphens."""
    heading = re.sub(r"[`*_]", "", heading.strip().lower())
    heading = re.sub(r"[^\w\- ]", "", heading)
    return heading.replace(" ", "-")


def _anchors(path: Path) -> set[str]:
    text = _CODE_FENCE.sub("", path.read_text())
    return {_slug(h) for h in _HEADING.findall(text)}


def _links(path: Path) -> list[str]:
    return _LINK.findall(_CODE_FENCE.sub("", path.read_text()))


def test_doc_files_exist():
    assert (ROOT / "docs" / "serving.md").exists(), \
        "docs/serving.md is the serving-subsystem architecture doc"
    for doc in DOCS:
        assert doc.exists(), doc


@pytest.mark.parametrize("doc", DOCS, ids=lambda p: p.name)
def test_markdown_links_resolve(doc):
    for target in _links(doc):
        if target.startswith(("http://", "https://", "mailto:")):
            continue                     # external: not checked offline
        path_part, _, anchor = target.partition("#")
        dest = doc if not path_part else (doc.parent / path_part).resolve()
        assert dest.exists(), f"{doc.name}: broken link -> {target}"
        if anchor:
            assert dest.suffix == ".md", \
                f"{doc.name}: anchor on non-markdown target {target}"
            assert anchor in _anchors(dest), \
                f"{doc.name}: dangling anchor -> {target}"


@pytest.mark.parametrize("doc", DOCS, ids=lambda p: p.name)
def test_referenced_repo_paths_exist(doc):
    """Backtick-quoted repo paths (src/..., tests/..., benchmarks/...,
    docs/...) in the docs must exist — the cheap guard against docs
    drifting from a refactor."""
    text = _CODE_FENCE.sub("", doc.read_text())
    for m in re.finditer(
            r"`((?:src|tests|benchmarks|docs|examples)/[\w./\-]+?)`", text):
        path = m.group(1).rstrip(".")
        assert (ROOT / path).exists(), f"{doc.name}: stale path `{path}`"
