"""Substrate layers: data pipeline determinism, optimizer behaviour,
checkpoint fault-tolerance, train-loop recovery, serving engine."""
from __future__ import annotations

import json
import os
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data.pipeline import batch_for_step
from repro.models.api import build_model
from repro.optim import adamw_init, adamw_update, cosine_schedule
from repro.serving import ServingEngine
from repro.train import TrainLoop, make_train_step


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_data_deterministic_and_counted():
    a = batch_for_step(1000, 32, 4, seed=0, step=7)
    b = batch_for_step(1000, 32, 4, seed=0, step=7)
    c = batch_for_step(1000, 32, 4, seed=0, step=8)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert not np.array_equal(a["tokens"], c["tokens"])
    assert a["tokens"].shape == (4, 32) and a["labels"].shape == (4, 32)
    # causal LM: labels are next tokens
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])
    assert int(a["tokens"].max()) < 1000 and int(a["tokens"].min()) >= 0


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_optimizes_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = adamw_init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(200):
        grads = jax.grad(loss)(params)
        params, opt, _ = adamw_update(params, grads, opt,
                                      lr=jnp.float32(0.05), weight_decay=0.0)
    assert float(loss(params)) < 1e-2


def test_grad_clipping_caps_update():
    params = {"w": jnp.zeros(3)}
    opt = adamw_init(params)
    huge = {"w": jnp.asarray([1e6, 0.0, 0.0])}
    _, _, metrics = adamw_update(params, huge, opt, lr=jnp.float32(1.0),
                                 clip_norm=1.0)
    assert float(metrics["grad_norm"]) == pytest.approx(1e6)


def test_cosine_schedule_shape():
    lrs = [float(cosine_schedule(jnp.asarray(s), base_lr=1.0, warmup=10,
                                 total=100)) for s in range(100)]
    assert lrs[0] < lrs[9]                       # warmup ramps
    assert lrs[10] == pytest.approx(1.0, rel=0.1)
    assert lrs[99] < 0.2                          # decayed
    assert min(lrs[10:]) >= 0.099                 # min_frac floor


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_and_gc(tmp_path):
    cm = CheckpointManager(tmp_path, keep=2)
    tree = {"a": jnp.arange(6.0).reshape(2, 3), "b": {"c": jnp.ones(4)}}
    for s in (1, 2, 3):
        cm.save(s, tree, extra={"s": s})
    assert cm.steps() == [2, 3]  # keep-last-2
    got, step, extra = cm.restore(tree)
    assert step == 3 and extra == {"s": 3}
    np.testing.assert_array_equal(got["a"], tree["a"])


def test_checkpoint_corruption_detected(tmp_path):
    cm = CheckpointManager(tmp_path, keep=5)
    tree = {"a": jnp.ones(3)}
    cm.save(1, tree)
    cm.save(2, tree)
    # corrupt the latest
    arr = tmp_path / "step_0000000002" / "arrays.npz"
    arr.write_bytes(b"garbage")
    assert cm.steps() == [1]          # CRC catches it
    _, step, _ = cm.restore(tree)     # falls back to the last valid step
    assert step == 1


def test_checkpoint_atomicity_no_partial_dir(tmp_path):
    cm = CheckpointManager(tmp_path)
    tree = {"a": jnp.ones(3)}
    cm.save(5, tree)
    leftovers = [p for p in tmp_path.iterdir() if p.name.startswith(".tmp")]
    assert not leftovers


# ---------------------------------------------------------------------------
# train loop fault tolerance
# ---------------------------------------------------------------------------

def _small_loop(tmp_path, failure_injector=None, ckpt_every=2):
    cfg = get_config("gemma_2b", reduced=True)
    model = build_model(cfg)
    step = make_train_step(model, base_lr=1e-3, remat=False)
    return TrainLoop(model, cfg, step, seq_len=12, global_batch=2,
                     ckpt_dir=str(tmp_path), ckpt_every=ckpt_every,
                     failure_injector=failure_injector), cfg


def test_train_loop_runs_and_checkpoints(tmp_path):
    loop, _ = _small_loop(tmp_path)
    hist = loop.run(4)
    assert len(hist) == 4
    assert all(np.isfinite(h["loss"]) for h in hist)
    assert CheckpointManager(tmp_path).latest_step() == 4


def test_train_loop_recovers_from_transient_failure(tmp_path):
    boom = {"armed": True}

    def injector(step):
        if step == 3 and boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("injected node failure")

    loop, _ = _small_loop(tmp_path, failure_injector=injector)
    hist = loop.run(5)
    assert [h["step"] for h in hist][-1] == 4
    assert len(hist) >= 5  # every step completed despite the failure


def test_train_loop_resume_is_deterministic(tmp_path):
    loop1, _ = _small_loop(tmp_path, ckpt_every=2)
    h1 = loop1.run(2)          # checkpoints at step 2
    loop2, _ = _small_loop(tmp_path, ckpt_every=2)
    h2 = loop2.run(4)          # resumes from 2, runs 2..3
    assert h2[0]["step"] == 2
    # fresh full run for comparison
    loop3, _ = _small_loop(tmp_path / "fresh", ckpt_every=100)
    h3 = loop3.run(4)
    assert h3[2]["loss"] == pytest.approx(h2[0]["loss"], rel=1e-4)


# ---------------------------------------------------------------------------
# serving engine
# ---------------------------------------------------------------------------

def test_serving_greedy_deterministic():
    cfg = get_config("gemma_2b", reduced=True)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, max_len=32, batch=2)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                 cfg.vocab_size)
    a = eng.generate(prompts, steps=6)
    b = eng.generate(prompts, steps=6)
    np.testing.assert_array_equal(a, b)
    assert a.shape == (2, 6)


def test_serving_generation_matches_manual_decode():
    cfg = get_config("qwen3_8b", reduced=True)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, max_len=32, batch=1)
    prompts = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0,
                                 cfg.vocab_size)
    out = eng.generate(prompts, steps=4)
    # manual greedy loop
    cache = model.init_cache(1, 32, None)
    logits, cache = model.prefill(params, prompts, cache)
    toks = []
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for _ in range(4):
        toks.append(int(tok[0]))
        logits, cache = model.decode_step(params, tok, cache)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    assert toks == [int(x) for x in np.asarray(out[0])]
