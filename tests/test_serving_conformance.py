"""Family-conformance property suite for continuous (ragged) serving.

ONE parametrized harness runs the same assertions over EVERY config that
claims ``supports_ragged_serving()`` — the dense KV stacks (MHA / GQA /
SWA), the recurrent-state families (ssm / hybrid), MoE, *and* the ring-KV
SWA variants (``<arch>+ring``: O(window) per-slot caches) — with zero
per-family test duplication:

  * greedy token-for-token equivalence vs per-request lock-step generation
    at ``decode_ticks`` 1 and 8 (the single-tick and fused-block engines);
  * seeded temperature>0 replay invariance (same (seed, trace) replays
    token-for-token under timed arrivals; a different seed differs);
  * device-state zeroing after ``release_slot`` (lengths, recurrent state,
    and ring KV rows all return to the empty-context state).

The suite also pins the *gated* set: the only configs allowed to refuse
continuous batching are the cross-attention stacks (vlm / audio — per-slot
source KV would need its own pool). A config that claims support but
raises mid-flight, or a config that silently joins the gated set, fails
here. Ring variants serve a trace whose prompts all exceed the ring itself
(not just the window), so chunked prefill wraps on every request — the
harness asserts this against the reported ring size — and the position
budgets wrap the ring again during decode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models.api import build_model
from repro.serving import (ContinuousBatchingEngine, Request, ServingEngine,
                           poisson_trace)

jax.config.update("jax_platform_name", "cpu")

# ring-KV variants of the SWA archs ride the same harness as first-class
# configs (reduced window is 32; see _spec for the wrap-forcing trace)
RING_VARIANTS = ["h2o-danube-1.8b+ring", "hymba-1.5b+ring"]


def _claims(arch: str) -> bool:
    model = build_model(get_config(arch, reduced=True))
    return getattr(model, "supports_ragged_serving", lambda: False)()


RAGGED = [a for a in ARCH_IDS if _claims(a)] + RING_VARIANTS
GATED = [a for a in ARCH_IDS if not _claims(a)]

_MODELS: dict = {}


def _get(arch: str):
    if arch not in _MODELS:
        cfg = get_config(arch, reduced=True)
        model = build_model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        _MODELS[arch] = (cfg, model, params)
    return _MODELS[arch]


def _spec(arch: str) -> dict:
    """Per-config serving shape. Ring variants get a long-context trace:
    every prompt exceeds both the (reduced) window of 32 AND the 128-row
    ring — so chunked prefill itself wraps on every request (asserted in
    the harness, not just claimed) — and every position budget runs past
    the ring again during decode. That is the scenario a full cache of the
    same max_len could also hold, but at 2x the per-slot KV bytes (see
    test_ring_equivalence.py)."""
    if arch.endswith("+ring"):
        return dict(max_len=256, prompts=(130, 160), gens=(20, 40))
    return dict(max_len=64, prompts=(3, 18), gens=(3, 12))


def _trace(cfg, spec, *, n=4, seed=5, gens=None, rate=None):
    return poisson_trace(n_requests=n, vocab_size=cfg.vocab_size,
                         prompt_len=spec["prompts"],
                         max_new=gens or spec["gens"], seed=seed, rate=rate)


# ---------------------------------------------------------------------------
# the gated set is cross-attention stacks, exactly
# ---------------------------------------------------------------------------

def test_gated_set_is_cross_attention_only():
    assert set(GATED) == {"llama32_vision_90b", "whisper_small"}, (
        "supports_ragged_serving() gates must cover exactly the "
        "cross-attention stacks (per-slot source KV is not poolable yet)")
    for arch in GATED:
        model = build_model(get_config(arch, reduced=True))
        with pytest.raises(ValueError):
            ContinuousBatchingEngine(model, {}, n_slots=2, max_len=32,
                                     chunk=8)


# ---------------------------------------------------------------------------
# greedy equivalence: continuous == per-request, at both tick horizons
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("ticks", [1, 8])
@pytest.mark.parametrize("arch", RAGGED)
def test_greedy_matches_per_request(arch, ticks):
    """Every request's continuous-batching output equals its single-request
    lock-step generation token-for-token — batch composition, chunked
    prefill interleaving, slot reuse, and the fused tick horizon must all
    be invisible to any individual request."""
    cfg, model, params = _get(arch)
    spec = _spec(arch)
    trace = _trace(cfg, spec)
    ref = ServingEngine(model, params, max_len=spec["max_len"], batch=1)
    want = {r.rid: np.asarray(ref.generate(
        jnp.asarray(r.prompt)[None], steps=r.max_new_tokens))[0].tolist()
        for r in trace}
    eng = ContinuousBatchingEngine(model, params, n_slots=2,
                                   max_len=spec["max_len"], chunk=8,
                                   decode_ticks=ticks)
    report = eng.run(list(trace))
    got = {r["rid"]: r["tokens"] for r in report["requests"]}
    assert got == want, (arch, ticks)
    agg = report["aggregate"]
    assert agg["n_retired"] == len(trace) and agg["n_rejected"] == 0
    assert eng.pool.n_free == 2                    # all slots returned
    if arch.endswith("+ring"):
        # the long-context claim must actually hold: every prompt is longer
        # than the ring, so chunked prefill wrapped on every request
        rows = agg["kv_rows_per_slot"]
        assert rows < spec["max_len"]
        assert all(len(r.prompt) > rows for r in trace), (
            "ring trace no longer wraps chunked prefill")


# ---------------------------------------------------------------------------
# seeded sampling: replay invariance
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", RAGGED)
def test_seeded_sampling_replays(arch):
    """temperature > 0 streams are a function of (seed, trace) only: keys
    derive from (seed, admission serial, token index), so timed arrivals —
    which change how prefill chunks and decode blocks interleave — cannot
    perturb a draw; a different seed must draw a different stream."""
    cfg, model, params = _get(arch)
    spec = _spec(arch)
    trace = _trace(cfg, spec, n=3, seed=3, gens=(4, 10), rate=100.0)

    def run(seed):
        eng = ContinuousBatchingEngine(model, params, n_slots=2,
                                       max_len=spec["max_len"], chunk=8,
                                       temperature=0.8, seed=seed,
                                       decode_ticks=4)
        rep = eng.run(list(trace))
        return {r["rid"]: r["tokens"] for r in rep["requests"]}

    first = run(7)
    assert run(7) == first, arch
    assert run(8) != first, arch


# ---------------------------------------------------------------------------
# release_slot: device state returns to the empty-context zero state
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", RAGGED)
def test_release_zeroes_slot_state(arch):
    """After every request retires, each family's per-slot decode state is
    all-zeros: lengths always; recurrent state (RWKV x_prev/wkv, Mamba
    conv/ssm) because it feeds forward multiplicatively; ring KV rows
    because the ring reset contract is uniform and inspectable. (Full-cache
    KV rows are intentionally NOT zeroed — stale rows past len=0 are never
    attended, and the next occupant overwrites in place.)"""
    cfg, model, params = _get(arch)
    spec = _spec(arch)
    eng = ContinuousBatchingEngine(model, params, n_slots=2,
                                   max_len=spec["max_len"], chunk=8,
                                   decode_ticks=4)
    report = eng.run(_trace(cfg, spec, n=3, seed=9))
    assert report["aggregate"]["n_retired"] == 3
    assert eng.pool.n_free == 2
    cache = eng.cache
    assert not np.any(np.asarray(cache["len"])), arch
    zeroed = ["rwkv_att", "rwkv_ffn", "rwkv_wkv", "mamba_conv", "mamba_ssm"]
    if cfg.kv_ring and cfg.window:
        zeroed += ["k", "v"]
    for key in zeroed:
        if key in cache:
            assert not np.any(np.asarray(cache[key])), (arch, key)
