"""Family-conformance property suite for continuous (ragged) serving.

ONE parametrized harness runs the same assertions over EVERY config that
claims ``supports_ragged_serving()`` — the dense KV stacks (MHA / GQA /
SWA), the recurrent-state families (ssm / hybrid), MoE, the ring-KV
SWA variants (``<arch>+ring``: O(window) per-slot caches), *and* the
cross-attention stacks (vlm / audio, served through the source-KV pool) —
with zero per-family test duplication:

  * greedy token-for-token equivalence vs per-request lock-step generation
    at ``decode_ticks`` 1 and 8 (the single-tick and fused-block engines);
  * seeded temperature>0 replay invariance (same (seed, trace) replays
    token-for-token under timed arrivals; a different seed differs);
  * device-state zeroing after ``release_slot`` (lengths, recurrent state,
    and ring KV rows all return to the empty-context state).

The suite also pins the *gated* set: it is **empty** — every config serves
ragged. A config that claims support but raises mid-flight, or a config
that silently starts refusing, fails here. Ring variants serve a trace
whose prompts all exceed the ring itself (not just the window), so chunked
prefill wraps on every request — the harness asserts this against the
reported ring size — and the position budgets wrap the ring again during
decode.

Cross-attention stacks additionally run a source-bearing section (the
shared harness above drives them sourceless — cross terms exactly zero on
both engines): greedy equivalence and seeded replay over traces with
*heterogeneous* source lengths and shared source ids (pool dedup), plus
the source-KV pool's release contract — a retired request's entry rows
are zeroed once its last holder leaves, and a backfilled request never
reads its predecessor's encoder state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models.api import build_model
from repro.serving import (ContinuousBatchingEngine, Request, ServingEngine,
                           poisson_trace)

jax.config.update("jax_platform_name", "cpu")

# ring-KV variants of the SWA archs ride the same harness as first-class
# configs (reduced window is 32; see _spec for the wrap-forcing trace)
RING_VARIANTS = ["h2o-danube-1.8b+ring", "hymba-1.5b+ring"]


def _claims(arch: str) -> bool:
    model = build_model(get_config(arch, reduced=True))
    return getattr(model, "supports_ragged_serving", lambda: False)()


RAGGED = [a for a in ARCH_IDS if _claims(a)] + RING_VARIANTS
GATED = [a for a in ARCH_IDS if not _claims(a)]

_MODELS: dict = {}


def _get(arch: str):
    if arch not in _MODELS:
        cfg = get_config(arch, reduced=True)
        model = build_model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        _MODELS[arch] = (cfg, model, params)
    return _MODELS[arch]


def _spec(arch: str) -> dict:
    """Per-config serving shape. Ring variants get a long-context trace:
    every prompt exceeds both the (reduced) window of 32 AND the 128-row
    ring — so chunked prefill itself wraps on every request (asserted in
    the harness, not just claimed) — and every position budget runs past
    the ring again during decode. That is the scenario a full cache of the
    same max_len could also hold, but at 2x the per-slot KV bytes (see
    test_ring_equivalence.py)."""
    if arch.endswith("+ring"):
        return dict(max_len=256, prompts=(130, 160), gens=(20, 40))
    return dict(max_len=64, prompts=(3, 18), gens=(3, 12))


def _trace(cfg, spec, *, n=4, seed=5, gens=None, rate=None):
    return poisson_trace(n_requests=n, vocab_size=cfg.vocab_size,
                         prompt_len=spec["prompts"],
                         max_new=gens or spec["gens"], seed=seed, rate=rate)


# ---------------------------------------------------------------------------
# the gated set is empty: every family serves ragged
# ---------------------------------------------------------------------------

def test_gated_set_is_empty():
    """Cross-attention stacks were the last gated family; the source-KV
    pool (encoder-side K/V ingested once per source id, shared read-only
    across a request's decode ticks) lifted that, so every config now
    claims — and is held to, by the harness below — ragged serving."""
    assert GATED == [], (
        "supports_ragged_serving() must hold for every config — the "
        f"gated set is pinned empty, got {GATED}")


# ---------------------------------------------------------------------------
# greedy equivalence: continuous == per-request, at both tick horizons
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("ticks", [1, 8])
@pytest.mark.parametrize("arch", RAGGED)
def test_greedy_matches_per_request(arch, ticks):
    """Every request's continuous-batching output equals its single-request
    lock-step generation token-for-token — batch composition, chunked
    prefill interleaving, slot reuse, and the fused tick horizon must all
    be invisible to any individual request."""
    cfg, model, params = _get(arch)
    spec = _spec(arch)
    trace = _trace(cfg, spec)
    ref = ServingEngine(model, params, max_len=spec["max_len"], batch=1)
    want = {r.rid: np.asarray(ref.generate(
        jnp.asarray(r.prompt)[None], steps=r.max_new_tokens))[0].tolist()
        for r in trace}
    eng = ContinuousBatchingEngine(model, params, n_slots=2,
                                   max_len=spec["max_len"], chunk=8,
                                   decode_ticks=ticks)
    report = eng.run(list(trace))
    got = {r["rid"]: r["tokens"] for r in report["requests"]}
    assert got == want, (arch, ticks)
    agg = report["aggregate"]
    assert agg["n_retired"] == len(trace) and agg["n_rejected"] == 0
    assert eng.pool.n_free == 2                    # all slots returned
    if arch.endswith("+ring"):
        # the long-context claim must actually hold: every prompt is longer
        # than the ring, so chunked prefill wrapped on every request
        rows = agg["kv_rows_per_slot"]
        assert rows < spec["max_len"]
        assert all(len(r.prompt) > rows for r in trace), (
            "ring trace no longer wraps chunked prefill")


# ---------------------------------------------------------------------------
# seeded sampling: replay invariance
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", RAGGED)
def test_seeded_sampling_replays(arch):
    """temperature > 0 streams are a function of (seed, trace) only: keys
    derive from (seed, admission serial, token index), so timed arrivals —
    which change how prefill chunks and decode blocks interleave — cannot
    perturb a draw; a different seed must draw a different stream."""
    cfg, model, params = _get(arch)
    spec = _spec(arch)
    trace = _trace(cfg, spec, n=3, seed=3, gens=(4, 10), rate=100.0)

    def run(seed):
        eng = ContinuousBatchingEngine(model, params, n_slots=2,
                                       max_len=spec["max_len"], chunk=8,
                                       temperature=0.8, seed=seed,
                                       decode_ticks=4)
        rep = eng.run(list(trace))
        return {r["rid"]: r["tokens"] for r in rep["requests"]}

    first = run(7)
    assert run(7) == first, arch
    assert run(8) != first, arch


# ---------------------------------------------------------------------------
# release_slot: device state returns to the empty-context zero state
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", RAGGED)
def test_release_zeroes_slot_state(arch):
    """After every request retires, each family's per-slot decode state is
    all-zeros: lengths always; recurrent state (RWKV x_prev/wkv, Mamba
    conv/ssm) because it feeds forward multiplicatively; ring KV rows
    because the ring reset contract is uniform and inspectable. (Full-cache
    KV rows are intentionally NOT zeroed — stale rows past len=0 are never
    attended, and the next occupant overwrites in place.)"""
    cfg, model, params = _get(arch)
    spec = _spec(arch)
    eng = ContinuousBatchingEngine(model, params, n_slots=2,
                                   max_len=spec["max_len"], chunk=8,
                                   decode_ticks=4)
    report = eng.run(_trace(cfg, spec, n=3, seed=9))
    assert report["aggregate"]["n_retired"] == 3
    assert eng.pool.n_free == 2
    cache = eng.cache
    assert not np.any(np.asarray(cache["len"])), arch
    zeroed = ["rwkv_att", "rwkv_ffn", "rwkv_wkv", "mamba_conv", "mamba_ssm"]
    if cfg.kv_ring and cfg.window:
        zeroed += ["k", "v"]
    for key in zeroed:
        if key in cache:
            assert not np.any(np.asarray(cache[key])), (arch, key)


# ---------------------------------------------------------------------------
# cross-attention stacks: the source-KV pool properties (vlm / audio)
# ---------------------------------------------------------------------------

XATTN = ["llama32_vision_90b", "whisper_small"]


def _source_trace(cfg, *, n=4, seed=11, rate=None):
    """Source-bearing trace with heterogeneous encoder lengths AND a shared
    source id: requests 1 and 3 present the same (id, features) pair, the
    rest carry private sources of different lengths — so one trace
    exercises per-slot length masking, pool dedup, and entry reuse."""
    rng = np.random.default_rng(seed)
    src_max = cfg.source_len
    shared = (rng.standard_normal((src_max - 4, cfg.d_model))
              .astype(np.float32) * 0.02)
    arrivals = (np.zeros(n) if rate is None
                else np.cumsum(rng.exponential(1.0 / rate, n)))
    reqs = []
    for i in range(n):
        if i % 2:
            src, sid = shared, "shared-src"
        else:
            ln = int(rng.integers(4, src_max + 1))
            src = (rng.standard_normal((ln, cfg.d_model))
                   .astype(np.float32) * 0.02)
            sid = None
        p = int(rng.integers(3, 18))
        reqs.append(Request(
            prompt=rng.integers(0, cfg.vocab_size, p).astype(np.int32),
            max_new_tokens=int(rng.integers(3, 12)), rid=i,
            arrival=float(arrivals[i]), source=src, source_id=sid))
    return reqs


def _per_request_with_source(cfg, model, params, reqs, *, max_len=64):
    """Per-request lock-step reference: each source padded to the pool row
    size and masked to its true length — the identical padded+masked math
    the continuous engine's ingest runs, so equality is exact."""
    src_max = cfg.source_len
    ref = ServingEngine(model, params, max_len=max_len, batch=1,
                        source_len=src_max)
    want = {}
    for r in reqs:
        pad = np.zeros((1, src_max, cfg.d_model), np.float32)
        pad[0, :len(r.source)] = r.source
        want[r.rid] = np.asarray(ref.generate(
            jnp.asarray(r.prompt)[None], steps=r.max_new_tokens,
            source=jnp.asarray(pad),
            source_len=jnp.asarray([len(r.source)], jnp.int32)))[0].tolist()
    return want


@pytest.mark.parametrize("ticks", [1, 8])
@pytest.mark.parametrize("arch", XATTN)
def test_xattn_greedy_matches_per_request_with_sources(arch, ticks):
    """Continuous cross-attention serving == per-request generation,
    token for token, on a trace whose rows carry *different* encoder
    lengths (coexisting in one static-shape dispatch) and a shared source
    id. The shared pair overlapping in flight must be served by ONE pooled
    ingest (the refcount share is asserted, not assumed)."""
    cfg, model, params = _get(arch)
    reqs = _source_trace(cfg)
    want = _per_request_with_source(cfg, model, params, reqs)
    eng = ContinuousBatchingEngine(model, params, n_slots=4, max_len=64,
                                   chunk=8, decode_ticks=ticks)
    report = eng.run(list(reqs))
    got = {r["rid"]: r["tokens"] for r in report["requests"]}
    assert got == want, (arch, ticks)
    agg = report["aggregate"]
    assert agg["n_retired"] == len(reqs) and agg["n_rejected"] == 0
    # all 4 slots admitted at once -> the shared pair overlapped in flight:
    # its second request must have ridden the first's entry
    assert agg["source_ingests"] == 3 and agg["source_shares"] == 1, agg


@pytest.mark.parametrize("arch", XATTN)
def test_xattn_seeded_sampling_replays_with_sources(arch):
    """Seeded sampling over source-bearing traces is a function of
    (seed, trace) only — timed arrivals perturb how ingests, prefill
    chunks, and decode blocks interleave, never a draw."""
    cfg, model, params = _get(arch)
    reqs = _source_trace(cfg, n=3, seed=13, rate=100.0)

    def run(seed):
        eng = ContinuousBatchingEngine(model, params, n_slots=2, max_len=64,
                                       chunk=8, temperature=0.8, seed=seed,
                                       decode_ticks=4)
        rep = eng.run(list(reqs))
        return {r["rid"]: r["tokens"] for r in rep["requests"]}

    first = run(7)
    assert run(7) == first, arch
    assert run(8) != first, arch


@pytest.mark.parametrize("arch", XATTN)
def test_xattn_release_zeroes_source_entries(arch):
    """After every request retires, the source-KV pool is all-zeros:
    entry K/V rows, src_len, and (trivially) nothing holds a reference —
    the uniform reset-on-release contract extended to the second pool."""
    cfg, model, params = _get(arch)
    eng = ContinuousBatchingEngine(model, params, n_slots=2, max_len=64,
                                   chunk=8, decode_ticks=4)
    report = eng.run(_source_trace(cfg, n=3, seed=17))
    assert report["aggregate"]["n_retired"] == 3
    assert eng.src_pool.n_free == eng.src_pool.n_entries
    cache = eng.cache
    for key in ("src_k", "src_v", "src_len"):
        assert not np.any(np.asarray(cache[key])), (arch, key)


@pytest.mark.parametrize("arch", XATTN)
def test_xattn_backfill_never_reads_predecessor_source(arch):
    """Entry-reuse isolation: request B backfills the slot (and pool
    entry) request A just vacated, with a *shorter* source — B's stream
    must equal its per-request generation exactly, i.e. nothing of A's
    encoder state (which occupied rows beyond B's length) leaks through
    the masked read. With n_slots=1 the reuse is forced, not incidental."""
    cfg, model, params = _get(arch)
    src_max = cfg.source_len
    rng = np.random.default_rng(23)
    src_a = rng.standard_normal((src_max, cfg.d_model)).astype(np.float32)
    src_b = (rng.standard_normal((4, cfg.d_model)).astype(np.float32)
             * 5.0)   # short + loud: a leak would move logits
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size, 5).astype(np.int32),
                    max_new_tokens=4, rid="a", source=src_a),
            Request(prompt=rng.integers(0, cfg.vocab_size, 7).astype(np.int32),
                    max_new_tokens=6, rid="b", source=src_b)]
    want = _per_request_with_source(cfg, model, params, reqs)
    eng = ContinuousBatchingEngine(model, params, n_slots=1, max_len=64,
                                   chunk=8)
    report = eng.run(list(reqs))
    got = {r["rid"]: r["tokens"] for r in report["requests"]}
    assert got == want, arch
    assert report["aggregate"]["source_ingests"] == 2


# ---------------------------------------------------------------------------
# +w4a8 quantized serving: the two-tier agreement/parity contract
# ---------------------------------------------------------------------------
# The fp32 harness above holds continuous serving to EXACT token equality
# against per-request lock-step generation. A quantized serving path cannot
# satisfy that contract against an fp32 reference, and on random-init
# reduced models it cannot even satisfy a free-running token-agreement
# threshold against the fp32 twin: W4 weight noise perturbs logits by far
# more than the typical top-2 gap, so trajectories fork at the first
# sampled token regardless of engine correctness (see docs/serving.md,
# "Quantized serving" — the probe test at the bottom pins the *logits*
# divergence instead, which is the quantity quantization actually bounds).
#
# What the engines CAN be held to — and are, here — is a two-tier fork:
#
#   exact tier   — at *matched* quantization, engine mechanics must be
#                  invisible: (a) batched continuous == the same requests
#                  run one-at-a-time through an identically-configured
#                  continuous engine (batch-composition invisibility at
#                  int8, bit-exact); (b) for single-chunk prompts,
#                  continuous == quantized per-request lock-step, bit-exact
#                  (chunked prefill attends the current chunk's own
#                  positions through fresh fp K/V, so with no multi-chunk
#                  prefix there is no int8 re-read to diverge through).
#   measured tier — multi-chunk chunked prefill re-reads the *prefix*
#                  through the int8 cache while lock-step full prefill
#                  attends fresh fp K/V everywhere; that one difference is
#                  real quantization noise, so cross-engine token agreement
#                  is gated by per-variant floors pinned from measurement
#                  (seed 6; ticks 1 and 8 measured identical — the fork,
#                  when it happens, is at prefill, not in the decode loop).

W4A8_AGREEMENT_FLOORS = {
    # variant: (measured @ seed 6, pinned floor)  — floor is the ISSUE's
    # 0.90 default wherever measurement supports it, else measured - margin.
    # Pinned against bf16 scale planes: agreement sits on top-2 logit gaps,
    # so the scale dtype shifts which variants land near a tie — any future
    # deliberate change to quant numerics must re-measure this matrix.
    "qwen3_8b+w4a8": 0.78,              # measured 0.821
    "whisper_small+w4a8": 0.78,         # measured 0.821 (sourceless)
    "llama2_7b+w4a8": 0.90,             # measured 1.000
    "llama4_scout_17b_16e+w4a8": 0.90,  # measured 1.000 (MoE routing
    #   amplifies prefix noise when a flipped top-k expert forks the
    #   trajectory — under bf16 scales this trace stays on the fp path)
    "llama32_vision_90b+w4a8": 0.45,    # measured 0.538 (smallest top-2
    #   gaps of the family set — the token cliff, honestly pinned)
    "h2o_danube_1p8b+ring+w4a8": 0.90,  # measured 1.000 (moderate trace)
    "hymba_1p5b+ring+w4a8": 0.90,       # measured 0.984 (moderate trace)
}

# single-chunk exactness + batch-composition spans: attention geometry
# (GQA/MHA), MoE, vlm cross, recurrent, and ring families
W4A8_EXACT = ["qwen3_8b+w4a8", "llama32_vision_90b+w4a8",
              "llama4_scout_17b_16e+w4a8", "rwkv6_3b+w4a8",
              "mistral_nemo_12b+w4a8"]
W4A8_BATCH_COMP = ["llama32_vision_90b+w4a8", "llama4_scout_17b_16e+w4a8",
                   "h2o_danube_1p8b+ring+w4a8"]


def _w4a8_spec(arch: str) -> dict:
    """Ring+w4a8 uses a moderate wrap trace: prompts exceed the reduced
    window (32) so chunked prefill wraps, but the prefix the int8 re-read
    can drift over is bounded — the fp32 rings' (130, 160) trace compounds
    int8 prefix noise over ~20 wrap chunks, which belongs to the measured
    tier's *why*, not to a stable floor."""
    if "+ring" in arch:
        return dict(max_len=256, prompts=(40, 60), gens=(10, 20))
    return dict(max_len=64, prompts=(3, 18), gens=(3, 12))


def _w4a8_pair(arch: str):
    """(cfg, model, fp32 params) for a +w4a8 variant — params are the BASE
    config's init (quantization happens inside the engines, one-shot), so
    both engines in any comparison quantize the identical tree."""
    if arch not in _MODELS:
        cfg = get_config(arch, reduced=True)
        model = build_model(cfg)
        base = arch.replace("+w4a8", "")
        params = build_model(get_config(base, reduced=True)).init_params(
            jax.random.PRNGKey(0))
        _MODELS[arch] = (cfg, model, params)
    return _MODELS[arch]


def test_w4a8_axis_is_opt_in_and_exact_set_unchanged():
    """The +w4a8 axis is strictly opt-in: no base config carries it (the
    exact-tier fp32 harness membership above is pinned unchanged), every
    base composes with it, and it stacks with +ring."""
    for arch in ARCH_IDS:
        assert not getattr(get_config(arch, reduced=True), "w4a8_serve",
                           False), arch
    for arch in RING_VARIANTS:
        assert not get_config(arch, reduced=True).w4a8_serve, arch
    for arch in ARCH_IDS:
        assert get_config(arch + "+w4a8", reduced=True).w4a8_serve, arch
    rw = get_config("h2o_danube_1p8b+ring+w4a8", reduced=True)
    assert rw.w4a8_serve and rw.kv_ring


@pytest.mark.parametrize("arch", W4A8_BATCH_COMP)
def test_w4a8_batch_composition_exact(arch):
    """Exact tier (a): at matched quantization, batch composition is
    bit-invisible — the batched continuous run equals the same requests
    served one-at-a-time through an identically-configured continuous
    engine. This holds even for the variants whose lock-step agreement
    sits far below 1.0: the drift there is chunked-vs-full prefill, never
    slot sharing."""
    cfg, model, params = _w4a8_pair(arch)
    spec = _w4a8_spec(arch)
    trace = list(_trace(cfg, spec, seed=6))
    eng = ContinuousBatchingEngine(model, params, n_slots=2,
                                   max_len=spec["max_len"], chunk=8,
                                   decode_ticks=8)
    got = {r["rid"]: r["tokens"] for r in eng.run(trace)["requests"]}
    for r in trace:
        solo = ContinuousBatchingEngine(model, params, n_slots=2,
                                        max_len=spec["max_len"], chunk=8,
                                        decode_ticks=8)
        want = solo.run([r])["requests"][0]["tokens"]
        assert got[r.rid] == want, (arch, r.rid)


@pytest.mark.parametrize("arch", W4A8_EXACT)
def test_w4a8_single_chunk_matches_lockstep_exactly(arch):
    """Exact tier (b): prompts that fit one prefill chunk make continuous
    +w4a8 BIT-IDENTICAL to quantized per-request lock-step — the fresh-fp
    overlay means chunked prefill's only divergence channel is the
    multi-chunk prefix re-read, and here there is none."""
    cfg, model, params = _w4a8_pair(arch)
    spec = dict(max_len=64, prompts=(3, 8), gens=(3, 12))    # <= chunk
    trace = list(_trace(cfg, spec, seed=6))
    ref = ServingEngine(model, params, max_len=64, batch=1)
    want = {r.rid: np.asarray(ref.generate(
        jnp.asarray(r.prompt)[None], steps=r.max_new_tokens))[0].tolist()
        for r in trace}
    eng = ContinuousBatchingEngine(model, params, n_slots=2, max_len=64,
                                   chunk=8, decode_ticks=8)
    got = {r["rid"]: r["tokens"] for r in eng.run(trace)["requests"]}
    assert got == want, arch


@pytest.mark.parametrize("arch", sorted(W4A8_AGREEMENT_FLOORS))
def test_w4a8_agreement_floor_vs_lockstep(arch):
    """Measured tier: multi-chunk traces, greedy token agreement between
    continuous +w4a8 and the quantized lock-step twin is at or above the
    pinned per-variant floor (seed 6 — agreement is deterministic given
    (trace, seed, params), so a floor breach is a code regression, not
    noise)."""
    cfg, model, params = _w4a8_pair(arch)
    spec = _w4a8_spec(arch)
    trace = list(_trace(cfg, spec, seed=6))
    ref = ServingEngine(model, params, max_len=spec["max_len"], batch=1)
    eng = ContinuousBatchingEngine(model, params, n_slots=2,
                                   max_len=spec["max_len"], chunk=8,
                                   decode_ticks=8)
    got = {r["rid"]: r["tokens"] for r in eng.run(trace)["requests"]}
    match = total = 0
    for r in trace:
        want = np.asarray(ref.generate(
            jnp.asarray(r.prompt)[None],
            steps=r.max_new_tokens))[0].tolist()
        match += sum(a == b for a, b in zip(got[r.rid], want))
        total += len(want)
    rate = match / total
    assert rate >= W4A8_AGREEMENT_FLOORS[arch], (arch, rate)


def test_w4a8_seeded_sampling_replays():
    """quantize_params is deterministic (no RNG), so the fp32 replay
    contract carries over bit-for-bit: same (seed, trace) replays
    identically under timed arrivals, a different seed differs."""
    cfg, model, params = _w4a8_pair("qwen3_8b+w4a8")
    spec = _w4a8_spec("qwen3_8b+w4a8")
    trace = _trace(cfg, spec, n=3, seed=3, gens=(4, 10), rate=100.0)

    def run(seed):
        eng = ContinuousBatchingEngine(model, params, n_slots=2,
                                       max_len=spec["max_len"], chunk=8,
                                       temperature=0.8, seed=seed,
                                       decode_ticks=4)
        rep = eng.run(list(trace))
        return {r["rid"]: r["tokens"] for r in rep["requests"]}

    first = run(7)
    assert run(7) == first
    assert run(8) != first


@pytest.mark.parametrize("arch", ["qwen3_8b+w4a8",
                                  "h2o_danube_1p8b+ring+w4a8"])
def test_w4a8_release_zeroes_int8_rows_and_scales(arch):
    """After every request retires, released slots hold (rows 0, scale 0)
    — both planes, so stale int8 rows can never dequantize to a previous
    occupant's values even if misread. Full caches exempt the reserved
    parking row (max_len - 1): inactive rows in later decode blocks park
    scratch writes there by design, it is beyond every request's capacity
    and never attended. Rings have no parking row: fully zero."""
    cfg, model, params = _w4a8_pair(arch)
    spec = _w4a8_spec(arch)
    eng = ContinuousBatchingEngine(model, params, n_slots=2,
                                   max_len=spec["max_len"], chunk=8,
                                   decode_ticks=4)
    report = eng.run(_trace(cfg, spec, n=3, seed=9))
    assert report["aggregate"]["n_retired"] == 3
    cache = eng.cache
    assert not np.any(np.asarray(cache["len"]))
    ring = bool(cfg.kv_ring and cfg.window)
    for key in ("k", "v"):
        rows = np.asarray(cache[key])               # [L, B, S, Hkv, Dh]
        if not ring:
            rows = rows[:, :, :-1]
        assert not np.any(rows), (arch, key)
    for key in ("k_scale", "v_scale"):
        sc = np.asarray(cache[key])                 # [L, B, Hkv, S]
        if not ring:
            sc = sc[..., :-1]
        assert not np.any(sc), (arch, key)


def test_w4a8_release_zeroes_source_pool_scales():
    """The int8 source-KV pool's release contract: once the last holder
    of an entry retires, its rows AND its scale planes are zeroed."""
    cfg, model, params = _w4a8_pair("whisper_small+w4a8")
    eng = ContinuousBatchingEngine(model, params, n_slots=2, max_len=64,
                                   chunk=8, decode_ticks=4)
    report = eng.run(_source_trace(cfg, n=3, seed=17))
    assert report["aggregate"]["n_retired"] == 3
    assert eng.src_pool.n_free == eng.src_pool.n_entries
    cache = eng.cache
    assert cache["src_k"].dtype == jnp.int8
    for key in ("src_k", "src_v", "src_k_scale", "src_v_scale", "src_len"):
        assert not np.any(np.asarray(cache[key])), key


def test_w4a8_mid_block_eos_backfills():
    """Full admission lifecycle under quantization: a request that hits
    EOS mid-way through a fused 8-tick decode block retires with the EOS
    emitted, frees its slot, and the queued request backfills it — same
    contract as the fp32 engine, now over int8 state."""
    cfg, model, params = _w4a8_pair("qwen3_8b+w4a8")
    prompt = np.arange(5, dtype=np.int32)
    probe = ContinuousBatchingEngine(model, params, n_slots=1, max_len=64,
                                     chunk=8)
    free = probe.run([Request(prompt=prompt, max_new_tokens=8, rid="p")])
    toks = free["requests"][0]["tokens"]
    eos = toks[1]
    eng = ContinuousBatchingEngine(model, params, n_slots=1, max_len=64,
                                   chunk=8, eos_id=eos, decode_ticks=8)
    report = eng.run([Request(prompt=prompt, max_new_tokens=8, rid="a"),
                      Request(prompt=prompt + 1, max_new_tokens=3, rid="b")])
    by_rid = {r["rid"]: r for r in report["requests"]}
    assert by_rid["a"]["tokens"] == toks[:2]
    assert by_rid["a"]["finish_reason"] == "eos"
    assert by_rid["b"]["n_tokens"] >= 1
    assert eng.pool.n_free == 1


def test_w4a8_kv_bytes_per_slot_shrinks_4x():
    """The reported per-slot KV footprint of the int8 cache (rows + bf16
    scale planes) is 1/4 + 0.5/Dh of the fp32 twin's — the gauge includes
    the scale overhead, nothing is hidden in the ratio, and it stays
    under the 0.3x budget even at the reduced configs' Dh = 16."""
    def kv_bytes(arch):
        cfg, model, params = (_w4a8_pair(arch) if arch.endswith("+w4a8")
                              else _get(arch))
        eng = ContinuousBatchingEngine(model, params, n_slots=2, max_len=64,
                                       chunk=8)
        rep = eng.run([Request(prompt=np.arange(5, dtype=np.int32),
                               max_new_tokens=3, rid="x")])
        return rep["aggregate"]["kv_bytes_per_slot"]

    for base in ("qwen3_8b",):
        q, f = kv_bytes(base + "+w4a8"), kv_bytes(base)
        cfg = get_config(base, reduced=True)
        dh = cfg.resolved_head_dim
        assert q / f == pytest.approx(0.25 + 0.5 / dh, rel=1e-6), (q, f)
        assert q / f <= 0.3


W4A8_MAE_PROBE_CEILING = 0.5   # measured 0.20-0.40 across families


@pytest.mark.parametrize("arch", ["qwen3_8b", "llama4_scout_17b_16e",
                                  "llama32_vision_90b", "whisper_small"])
def test_w4a8_logits_mae_probe_vs_fp32_twin(arch):
    """The fp32-twin tier: free-running token agreement vs fp32 is the
    wrong gauge for W4 noise (it cliffs on top-2 gaps), so the fp32
    comparison is pinned where quantization actually bounds something —
    prefill logits MAE on a probe batch, normalized by the fp32 logit
    spread. Measured 0.20-0.40 across families; 0.5 is the ceiling."""
    from repro.models.quantized import quantize_params
    cfg, model, params = _get(arch)
    qparams = quantize_params(params)
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 16)),
                          jnp.int32)
    cache_fp = model.init_cache(4, 64, kv_dtype=jnp.float32)
    cache_q = model.init_cache(4, 64, kv_dtype=jnp.int8)
    lf, _ = jax.jit(model.prefill)(params, prompts, cache_fp, None, None)
    lq, _ = jax.jit(model.prefill)(qparams, prompts, cache_q, None, None)
    lf = np.asarray(lf, np.float64)
    lq = np.asarray(lq, np.float64)
    ratio = np.abs(lq - lf).mean() / lf.std()
    assert ratio < W4A8_MAE_PROBE_CEILING, (arch, ratio)
    assert ratio > 0.0                      # the probe actually measures
