"""Decoder-specialized RoPE (paper Eq. 11): the incremental angle-addition
recurrence must track direct cos/sin over long horizons."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import rope


def test_direct_rope_rotates_pairs():
    d = 8
    x = jnp.ones((1, d), jnp.float32)
    out = rope.apply_rope(x, jnp.asarray([0]))
    np.testing.assert_allclose(out, x)  # position 0: identity
    out1 = rope.apply_rope(x, jnp.asarray([3]))
    assert not np.allclose(out1, x)
    # norm preserved per pair (rotation)
    x1, x2 = out1[0, :d // 2], out1[0, d // 2:]
    np.testing.assert_allclose(np.asarray(x1 ** 2 + x2 ** 2),
                               np.full(d // 2, 2.0), rtol=1e-5)


@pytest.mark.parametrize("steps", [1, 7, 100])
def test_incremental_matches_direct(steps):
    d = 64
    st = rope.rope_state_init(d)
    for _ in range(steps):
        st = rope.rope_state_advance(st)
    want = rope.rope_state_init(d, position=steps)
    np.testing.assert_allclose(st.cos_m, want.cos_m, atol=1e-4)
    np.testing.assert_allclose(st.sin_m, want.sin_m, atol=1e-4)


def test_incremental_drift_50k_steps():
    """fp32 drift of the Eq. 11 recurrence over 50k decode steps (the FPGA
    never decodes this far; we quantify it for the 500k-context shape —
    advance in f64 matches, f32 drift stays below attention-relevant scale)."""
    d = 64
    st = rope.rope_state_init(d)
    for _ in range(50_000):
        st = rope.rope_state_advance(st)
    want = rope.rope_state_init(d, position=50_000)
    drift = np.max(np.abs(np.asarray(st.cos_m - want.cos_m)))
    assert drift < 5e-2, drift  # documented drift bound (DESIGN.md §6)


def test_apply_from_state_equals_direct_apply():
    d = 32
    x = jnp.asarray(np.random.default_rng(0).standard_normal((3, d)),
                    jnp.float32)
    m = 17
    st = rope.rope_state_init(d, position=m)
    got = rope.apply_rope_from_state(x, st)
    want = rope.apply_rope(x[:, None, :], jnp.asarray([m]))[:, 0, :]
    np.testing.assert_allclose(got, want, atol=1e-5)


def test_partial_rotary():
    d, rd = 32, 16
    x = jnp.asarray(np.random.default_rng(0).standard_normal((1, d)),
                    jnp.float32)
    out = rope.apply_rope(x, jnp.asarray([5]), rotary_dim=rd)
    # channels beyond rotary_dim pass through
    np.testing.assert_array_equal(out[0, rd:], x[0, rd:])
    assert not np.allclose(out[0, :rd], x[0, :rd])


def test_rope_preserves_attention_scores_shift_invariance():
    """RoPE's defining property: q·k depends only on relative position."""
    d = 64
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((1, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, d)), jnp.float32)

    def score(m_q, m_k):
        qr = rope.apply_rope(q, jnp.asarray([m_q]))
        kr = rope.apply_rope(k, jnp.asarray([m_k]))
        return float(qr[0] @ kr[0])

    assert score(5, 3) == pytest.approx(score(105, 103), rel=1e-4)
    assert score(0, 0) == pytest.approx(score(50, 50), rel=1e-4)
