"""Pallas gemv_w4a8 kernel: sweep vs oracle (interpret mode)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quantization import quantize_w4
from repro.kernels.gemv_w4a8 import ops, ref

RNG = np.random.default_rng(3)

SWEEP = [
    # m,  k,    n
    (1, 512, 512),      # GEMV
    (8, 1024, 512),
    (3, 768, 1024),     # non-block m
    (16, 512, 256),
    (32, 2048, 1024),   # GEMM-ish
]


@pytest.mark.parametrize("m,k,n", SWEEP)
def test_kernel_vs_oracle(m, k, n):
    x = jnp.asarray(RNG.standard_normal((m, k)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((k, n)) * 0.05, jnp.float32)
    qw = quantize_w4(w)
    got = ops.gemv_w4a8(x, qw.packed, qw.scale, interpret=True)
    want = ref.gemv_w4a8_ref(x, qw.packed, qw.scale)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)


def test_kernel_batched_lead_dims():
    x = jnp.asarray(RNG.standard_normal((2, 3, 512)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((512, 256)) * 0.05, jnp.float32)
    qw = quantize_w4(w)
    got = ops.gemv_w4a8(x, qw.packed, qw.scale, interpret=True)
    assert got.shape == (2, 3, 256)
    want = ref.gemv_w4a8_ref(x.reshape(-1, 512), qw.packed,
                             qw.scale).reshape(2, 3, 256)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)


def test_kernel_int_accumulation_matches_ref():
    """int32 partials are exact; the f32 group-rescale accumulation order
    differs between kernel (sequential k-blocks) and oracle (einsum + sum),
    so agreement is to f32 tolerance, not bit-exact."""
    x = jnp.asarray(RNG.standard_normal((8, 512)) * 10, jnp.float32)
    w = jnp.asarray(RNG.standard_normal((512, 512)), jnp.float32)
    qw = quantize_w4(w)
    got = ops.gemv_w4a8(x, qw.packed, qw.scale, interpret=True)
    want = ref.gemv_w4a8_ref(x, qw.packed, qw.scale)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_end_to_end_linear_quality():
    """W4A8 linear error vs the float matmul: RTN int4 floors at ~10.5% on
    gaussian weights (MSE-optimal clip) — the bound documents that floor."""
    x = jnp.asarray(RNG.standard_normal((4, 1024)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((1024, 512)) * 0.03, jnp.float32)
    qw = quantize_w4(w)
    got = ops.gemv_w4a8(x, qw.packed, qw.scale, interpret=True)
    want = x @ w
    rel = float(jnp.linalg.norm(got - want) / jnp.linalg.norm(want))
    assert rel < 0.13, rel


# non-dividing block shapes: the wrapper pads K to block_k and N to block_n
# internally — every (m, k, n) that isn't a multiple of the kernel tiling
# must still match the unpadded oracle (the serving models' d_ff / head
# concat dims are rarely tile-multiples at reduced test shapes)
ODD_SWEEP = [
    # m,  k,    n,   bm, bn, bk
    (1, 384, 192, 8, 256, 512),     # k and n both below one block
    (5, 640, 704, 8, 256, 512),     # neither divides
    (2, 1280, 320, 8, 128, 256),    # k = 5 blocks, n = 2.5 blocks
    (7, 896, 130, 8, 128, 512),     # n barely over one block
    (13, 300, 258, 8, 256, 256),    # k not even a GROUP multiple
]


@pytest.mark.parametrize("m,k,n,bm,bn,bk", ODD_SWEEP)
def test_kernel_nondividing_blocks_vs_ref(m, k, n, bm, bn, bk):
    x = jnp.asarray(RNG.standard_normal((m, k)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((k, n)) * 0.05, jnp.float32)
    qw = quantize_w4(w)
    got = ops.gemv_w4a8(x, qw.packed, qw.scale, block_m=bm, block_n=bn,
                        block_k=bk, interpret=True)
    assert got.shape == (m, n)
    want = ref.gemv_w4a8_ref(x, qw.packed, qw.scale)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)


def test_ref_matches_core_reference_semantics():
    """kernels/gemv_w4a8.ref and core.quantization.w4a8_matmul_ref are the
    same semantics — the models' CPU fallback (layers.linear) uses the core
    one, the kernel tests pin against this one; they must not drift."""
    from repro.core.quantization import QuantizedLinear, w4a8_matmul_ref
    x = jnp.asarray(RNG.standard_normal((6, 384)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((384, 160)) * 0.05, jnp.float32)
    qw = quantize_w4(w)
    a = ref.gemv_w4a8_ref(x, qw.packed, qw.scale)
    b = w4a8_matmul_ref(x, QuantizedLinear(qw.packed, qw.scale, None))
    np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-5)
