"""Pallas gemv_w4a8 kernel: sweep vs oracle (interpret mode)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quantization import quantize_w4
from repro.kernels.gemv_w4a8 import ops, ref

RNG = np.random.default_rng(3)

SWEEP = [
    # m,  k,    n
    (1, 512, 512),      # GEMV
    (8, 1024, 512),
    (3, 768, 1024),     # non-block m
    (16, 512, 256),
    (32, 2048, 1024),   # GEMM-ish
]


@pytest.mark.parametrize("m,k,n", SWEEP)
def test_kernel_vs_oracle(m, k, n):
    x = jnp.asarray(RNG.standard_normal((m, k)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((k, n)) * 0.05, jnp.float32)
    qw = quantize_w4(w)
    got = ops.gemv_w4a8(x, qw.packed, qw.scale, interpret=True)
    want = ref.gemv_w4a8_ref(x, qw.packed, qw.scale)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)


def test_kernel_batched_lead_dims():
    x = jnp.asarray(RNG.standard_normal((2, 3, 512)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((512, 256)) * 0.05, jnp.float32)
    qw = quantize_w4(w)
    got = ops.gemv_w4a8(x, qw.packed, qw.scale, interpret=True)
    assert got.shape == (2, 3, 256)
    want = ref.gemv_w4a8_ref(x.reshape(-1, 512), qw.packed,
                             qw.scale).reshape(2, 3, 256)
    np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)


def test_kernel_int_accumulation_matches_ref():
    """int32 partials are exact; the f32 group-rescale accumulation order
    differs between kernel (sequential k-blocks) and oracle (einsum + sum),
    so agreement is to f32 tolerance, not bit-exact."""
    x = jnp.asarray(RNG.standard_normal((8, 512)) * 10, jnp.float32)
    w = jnp.asarray(RNG.standard_normal((512, 512)), jnp.float32)
    qw = quantize_w4(w)
    got = ops.gemv_w4a8(x, qw.packed, qw.scale, interpret=True)
    want = ref.gemv_w4a8_ref(x, qw.packed, qw.scale)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_end_to_end_linear_quality():
    """W4A8 linear error vs the float matmul: RTN int4 floors at ~10.5% on
    gaussian weights (MSE-optimal clip) — the bound documents that floor."""
    x = jnp.asarray(RNG.standard_normal((4, 1024)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((1024, 512)) * 0.03, jnp.float32)
    qw = quantize_w4(w)
    got = ops.gemv_w4a8(x, qw.packed, qw.scale, interpret=True)
    want = x @ w
    rel = float(jnp.linalg.norm(got - want) / jnp.linalg.norm(want))
    assert rel < 0.13, rel
