"""Batched GQA/MQA decode + single-pass blockwise prefill vs the naive
two-pass oracle; parity across decode impls (tokenwise / blockwise / kernel)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import attention as attn
from repro.kernels.swiftkv_decode.ref import swiftkv_decode_ref

RNG = np.random.default_rng(0)


def mk(b=2, hq=4, hkv=2, s=96, d=32, dtype=jnp.float32):
    q = jnp.asarray(RNG.standard_normal((b, hq, d)), dtype)
    k = jnp.asarray(RNG.standard_normal((b, s, hkv, d)), dtype)
    v = jnp.asarray(RNG.standard_normal((b, s, hkv, d)), dtype)
    lengths = jnp.asarray(RNG.integers(1, s + 1, (b,)), jnp.int32)
    return q, k, v, lengths


@pytest.mark.parametrize("impl", ["tokenwise", "blockwise", "kernel", "naive"])
def test_decode_impl_parity(impl):
    q, k, v, lengths = mk()
    got = attn.decode_attention(q, k, v, lengths, impl=impl, block_size=32)
    want = swiftkv_decode_ref(q, k, v, lengths)
    np.testing.assert_allclose(got, want, atol=2e-5)


@pytest.mark.parametrize("hq,hkv", [(8, 8), (8, 2), (8, 1)])  # MHA/GQA/MQA
def test_decode_head_layouts(hq, hkv):
    q, k, v, lengths = mk(hq=hq, hkv=hkv)
    got = attn.decode_attention(q, k, v, lengths, impl="blockwise",
                                block_size=32)
    want = swiftkv_decode_ref(q, k, v, lengths)
    np.testing.assert_allclose(got, want, atol=2e-5)


def test_decode_window():
    q, k, v, lengths = mk(s=128)
    got = attn.decode_attention(q, k, v, lengths, impl="blockwise",
                                window=40, block_size=32)
    want = swiftkv_decode_ref(q, k, v, lengths, window=40)
    np.testing.assert_allclose(got, want, atol=2e-5)


def _naive_prefill(q, k, v, *, causal, window=None, kv_len=None):
    b, sq, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    qg = jnp.swapaxes(q, 1, 2).reshape(b, hkv, g, sq, d).astype(jnp.float32)
    kc = jnp.swapaxes(k, 1, 2).astype(jnp.float32)
    vc = jnp.swapaxes(v, 1, 2).astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kc) / np.sqrt(d)
    pos_q = jnp.arange(sq)[:, None]
    pos_k = jnp.arange(skv)[None, :]
    valid = jnp.ones((sq, skv), bool)
    if causal:
        valid &= pos_k <= pos_q
    if window is not None:
        valid &= pos_k > pos_q - window
    if kv_len is not None:
        valid &= pos_k < kv_len
    s = jnp.where(valid[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, vc)
    return jnp.swapaxes(out.reshape(b, hq, sq, d), 1, 2).astype(q.dtype)


@pytest.mark.parametrize("causal,window", [(True, None), (True, 24),
                                           (False, None)])
def test_prefill_blockwise_vs_naive(causal, window):
    b, sq, hq, hkv, d = 2, 64, 4, 2, 32
    q = jnp.asarray(RNG.standard_normal((b, sq, hq, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, sq, hkv, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, sq, hkv, d)), jnp.float32)
    got = attn.prefill_attention(q, k, v, causal=causal, window=window,
                                 kv_block=16)
    want = _naive_prefill(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(got, want, atol=3e-5)


def test_prefill_cross_attention_kv_length():
    """Cross-attn: non-causal with a padded KV prefix (stub frontend)."""
    b, sq, skv, h, d = 2, 16, 40, 4, 16
    q = jnp.asarray(RNG.standard_normal((b, sq, h, d)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((b, skv, h, d)), jnp.float32)
    v = jnp.asarray(RNG.standard_normal((b, skv, h, d)), jnp.float32)
    kv_len = 25
    got = attn.prefill_attention(
        q, k, v, causal=False, kv_lengths=jnp.full((b,), kv_len, jnp.int32),
        kv_block=16)
    want = _naive_prefill(q, k, v, causal=False, kv_len=kv_len)
    np.testing.assert_allclose(got, want, atol=3e-5)


def test_bf16_decode_stays_close():
    q, k, v, lengths = mk(dtype=jnp.bfloat16, s=64)
    got = attn.decode_attention(q, k, v, lengths, impl="blockwise",
                                block_size=32).astype(jnp.float32)
    want = swiftkv_decode_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                              v.astype(jnp.float32), lengths)
    np.testing.assert_allclose(got, want, atol=3e-2)
