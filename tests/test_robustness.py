"""Overload-hardened serving: admission control, deadline enforcement,
fault injection, and the engine invariant auditor.

Three layers, mirroring the subsystem:

* **scheduler** — bounded-queue shed policies (reject / shed-oldest /
  degrade), typed rejection codes, and conservation across all five
  terminal states (host-only, no engine);
* **engine** — drain, cancellation, deadline enforcement, predicted-TTFT
  shedding, NaN-poison quarantine (victim-only, byte-identical bystanders),
  ingest / dispatch / delay faults, and a hypothesis-driven chaos soak over
  randomized :class:`FaultPlan`\\ s (zero leaks, deterministic replay);
* **auditor** — clean on a healthy engine, detects injected corruption,
  and perturbs nothing.

The with-knobs-off identity contract (an engine with no overload config,
no faults, no auditor runs the exact PR-6 host loop) is pinned by the
serving-conformance suite and the benchmark regression gate; here we pin
what the knobs *do*.
"""
from __future__ import annotations

import numpy as np
import pytest

import jax

from repro.configs import get_config
from repro.models.api import build_model
from repro.serving import (AuditViolation, ContinuousBatchingEngine,
                           EngineAuditor, Fault, FaultPlan, KVSlotPool,
                           OverloadConfig, Request, Scheduler, Telemetry,
                           poisson_trace)
from repro.serving.workload import _arrivals

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                    # pragma: no cover
    from _hypothesis_compat import given, settings, st

jax.config.update("jax_platform_name", "cpu")


def _reqs(n, *, plen=6, budget=5, vocab=64, **kw):
    rng = np.random.default_rng(7)
    return [Request(prompt=rng.integers(0, vocab, plen).astype(np.int32),
                    max_new_tokens=budget, rid=i, **kw) for i in range(n)]


# ---------------------------------------------------------------------------
# scheduler: overload policies + typed terminals (host-only)
# ---------------------------------------------------------------------------

def test_overload_config_validation():
    with pytest.raises(ValueError):
        OverloadConfig(max_queue=0)
    with pytest.raises(ValueError):
        OverloadConfig(max_queue=4, policy="panic")
    with pytest.raises(ValueError):
        OverloadConfig(max_queue=4, policy="degrade", degrade_factor=1.5)


def test_bounded_queue_reject_sheds_incoming():
    sched = Scheduler(KVSlotPool(2, max_len=64),
                      overload=OverloadConfig(max_queue=2, policy="reject"))
    states = [sched.submit(r) for r in _reqs(5)]
    assert [s.status for s in states[:2]] == ["queued", "queued"]
    for s in states[2:]:
        assert s.status == "shed" and s.code == "queue_full"
    assert len(sched.queue) == 2 and len(sched.shed) == 3
    sched.assert_conservation()


def test_bounded_queue_shed_oldest_evicts_head():
    sched = Scheduler(KVSlotPool(2, max_len=64),
                      overload=OverloadConfig(max_queue=2,
                                              policy="shed-oldest"))
    states = [sched.submit(r) for r in _reqs(4)]
    # newest requests stay queued; the queue head was evicted each time
    assert [s.rid for s in sched.queue] == [2, 3]
    assert [s.rid for s in sched.shed] == [0, 1]
    assert all(s.code == "queue_full" for s in sched.shed)
    assert states[3].status == "queued"
    sched.assert_conservation()


def test_bounded_queue_degrade_halves_budgets():
    sched = Scheduler(KVSlotPool(2, max_len=64),
                      overload=OverloadConfig(max_queue=2, policy="degrade",
                                              degrade_factor=0.5))
    for r in _reqs(3, budget=8):
        sched.submit(r)
    assert len(sched.queue) == 3           # degrade keeps everyone
    assert [s.request.max_new_tokens for s in sched.queue] == [4, 4, 4]
    assert all(s.degraded_from == 8 for s in sched.queue)
    assert sched.n_degraded == 3
    # floor at 1: repeated overload can't degrade a budget to zero
    for r in _reqs(4, budget=8)[3:]:
        sched.submit(r)
    assert all(s.request.max_new_tokens >= 1 for s in sched.queue)
    sched.assert_conservation()


def test_typed_rejection_and_terminal_codes():
    sched = Scheduler(KVSlotPool(2, max_len=16))
    big = Request(prompt=np.arange(4, dtype=np.int32), max_new_tokens=99,
                  rid="big")
    rej = sched.submit(big)
    assert rej.status == "rejected" and rej.code == "budget_too_large"
    ok = sched.submit(_reqs(1)[0])
    (adm,) = sched.admit(0.0)
    assert adm is ok and adm.slot is not None
    slot = sched.abort(adm, "nonfinite_logits", 1.0, error=True,
                       detail="errored: poisoned")
    assert slot == adm.slot or adm.slot is None
    assert adm.status == "errored" and adm.code == "nonfinite_logits"
    assert sched.errored == [adm] and sched.n_retired == 0
    sched.assert_conservation()


def test_request_deadline_validation():
    with pytest.raises(ValueError):
        Request(prompt=np.arange(4, dtype=np.int32), max_new_tokens=4,
                ttft_deadline_s=0.0)
    with pytest.raises(ValueError):
        Request(prompt=np.arange(4, dtype=np.int32), max_new_tokens=4,
                deadline_s=-1.0)


# ---------------------------------------------------------------------------
# workload shapes (host-only)
# ---------------------------------------------------------------------------

def test_arrivals_rate_none_is_backlogged_for_every_shape():
    rng = np.random.default_rng(0)
    for shape in ("poisson", "bursty", "heavy-tail"):
        assert not _arrivals(rng, 8, None, shape, 4, 1.5).any()


def test_arrivals_monotonic_and_seeded():
    for shape in ("poisson", "bursty", "heavy-tail"):
        a = _arrivals(np.random.default_rng(3), 64, 10.0, shape, 8, 1.5)
        b = _arrivals(np.random.default_rng(3), 64, 10.0, shape, 8, 1.5)
        assert np.array_equal(a, b), shape
        assert (np.diff(a) >= 0).all() and (a > 0).all(), shape


def test_bursty_arrivals_clump():
    a = _arrivals(np.random.default_rng(0), 64, 10.0, "bursty", 8, 1.5)
    gaps = np.diff(a)
    # intra-burst gaps are ~20x tighter than the 0.1s mean: the median gap
    # collapses while the long-run rate stays near 10 req/s
    assert np.median(gaps) < 0.1 / 4
    assert a[-1] > 64 / 10.0 * 0.3


def test_heavy_tail_requires_finite_mean():
    with pytest.raises(ValueError):
        _arrivals(np.random.default_rng(0), 8, 10.0, "heavy-tail", 8, 1.0)
    with pytest.raises(ValueError):
        _arrivals(np.random.default_rng(0), 8, 10.0, "nope", 8, 1.5)


def test_poisson_trace_shape_passthrough():
    a = poisson_trace(n_requests=12, vocab_size=64, rate=50.0,
                      shape="bursty", burst=4, seed=1)
    b = poisson_trace(n_requests=12, vocab_size=64, rate=50.0,
                      shape="bursty", burst=4, seed=1)
    assert [r.arrival for r in a] == [r.arrival for r in b]
    assert [r.prompt.tolist() for r in a] == [r.prompt.tolist() for r in b]


# ---------------------------------------------------------------------------
# engine: one reduced dense engine, reused across runs (run() resets state)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def eng():
    cfg = get_config("llama2-7b", reduced=True)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    e = ContinuousBatchingEngine(
        model, params, n_slots=2, max_len=64, chunk=8, decode_ticks=4,
        seed=0, telemetry=Telemetry(),
        overload=OverloadConfig(max_queue=64, policy="reject"))
    e.warmup()
    return e


@pytest.fixture(scope="module")
def trace(eng):
    return poisson_trace(n_requests=6, vocab_size=eng.model.cfg.vocab_size,
                         prompt_len=(4, 10), max_new=(4, 8), seed=11)


@pytest.fixture(scope="module")
def clean_tokens(eng, trace):
    report = eng.run(list(trace))
    assert report["aggregate"]["n_retired"] == len(trace)
    return {r["rid"]: r["tokens"] for r in report["requests"]}


def _tokens(report):
    return {r["rid"]: r["tokens"] for r in report["requests"]}


def _errored(report):
    return sorted(r["rid"] for r in report["requests"]
                  if r["status"] == "errored")


def test_engine_typed_rejects_in_report(eng, trace):
    bad = Request(prompt=np.zeros(eng.pool.capacity + 1, np.int32),
                  max_new_tokens=4, rid="too-long")
    report = eng.run(list(trace) + [bad])
    rec = {r["rid"]: r for r in report["requests"]}["too-long"]
    assert rec["status"] == "rejected" and rec["code"] == "prompt_too_long"
    assert report["aggregate"]["n_rejected"] == 1
    assert report["aggregate"]["n_retired"] == len(trace)


def test_poison_quarantines_only_victim(eng, trace, clean_tokens):
    victim = trace[2].rid
    eng.faults = FaultPlan([Fault("poison_nan", rid=victim)])
    try:
        report = eng.run(list(trace))
    finally:
        eng.faults = None
    assert _errored(report) == [victim]
    rec = {r["rid"]: r for r in report["requests"]}[victim]
    assert rec["code"] == "nonfinite_logits"
    # the victim keeps its pre-fault prefix (the prefill token), every
    # bystander stream is byte-identical to the fault-free run
    assert rec["tokens"] == clean_tokens[victim][:len(rec["tokens"])]
    assert len(rec["tokens"]) == 1
    for rid, toks in _tokens(report).items():
        if rid != victim:
            assert toks == clean_tokens[rid], rid
    assert eng.pool.n_used == 0
    assert report["aggregate"]["n_errored"] == 1
    assert eng.tel.counts()["fault"] == 1
    assert eng.tel.counts()["error_retire"] == 1


def test_benign_faults_keep_tokens_identical(eng, trace, clean_tokens):
    eng.faults = FaultPlan([Fault("dispatch_fail", block=1),
                            Fault("tick_delay", block=0, delay_s=1e-4)])
    try:
        report = eng.run(list(trace))
    finally:
        eng.faults = None
    assert _tokens(report) == clean_tokens
    assert report["aggregate"]["faults_fired"] == 2
    assert report["aggregate"]["dispatch_retries"] == 1
    assert report["aggregate"]["n_errored"] == 0


def test_drain_finishes_inflight_sheds_queued(eng, trace):
    eng.run([])                                   # reset run-scoped state
    for r in trace:
        eng.submit(r, now=0.0)
    eng.step(now=0.0)                             # two admitted, rest queued
    eng.drain()
    late = eng.submit(Request(prompt=np.zeros(6, np.int32),
                              max_new_tokens=4, rid="late"), now=0.1)
    assert late.status == "shed" and late.code == "drain"
    for i in range(200):
        if not eng.step(now=0.2 + i * 0.01):
            break
    eng.sched.assert_conservation()
    assert eng.sched.n_retired == 2               # the in-flight pair finish
    codes = {s.rid: s.code for s in eng.sched.shed}
    assert all(c == "drain" for c in codes.values()) and len(codes) == 5
    assert eng.pool.n_used == 0
    assert eng.tel.counts()["drain"] >= 1


def test_cancel_queued_and_inflight(eng, trace):
    eng.run([])
    for r in trace:
        eng.submit(r, now=0.0)
    eng.step(now=0.0)
    inflight = next(iter(eng.sched.decoding.values()),
                    None) or eng.sched.prefilling[0]
    queued = eng.sched.queue[0]
    eng.cancel(inflight.rid)
    eng.cancel(queued.rid)
    eng.cancel("no-such-rid")                     # dropped silently
    for i in range(200):
        if not eng.step(now=0.1 + i * 0.01):
            break
    eng.sched.assert_conservation()
    assert queued.status == "shed" and queued.code == "cancelled"
    assert inflight.status == "retired" and inflight.code == "cancelled"
    assert len(inflight.tokens) < inflight.request.max_new_tokens
    assert eng.sched.n_retired == len(trace) - 1  # cancelled one counts too


def test_deadline_enforced_in_flight_and_in_queue(eng):
    eng.run([])
    reqs = _reqs(4, plen=6, budget=40, vocab=eng.model.cfg.vocab_size,
                 deadline_s=0.05)
    for r in reqs:
        eng.submit(r, now=0.0)
    eng.step(now=0.0)                             # 2 in flight, 2 queued
    for i in range(200):                          # jump past every deadline
        if not eng.step(now=1.0 + i * 0.01):
            break
    eng.sched.assert_conservation()
    by_rid = {s.rid: s for s in eng.sched.all_states()}
    n_aborted = sum(1 for s in by_rid.values()
                    if s.status == "retired" and s.code == "deadline")
    n_shed = sum(1 for s in by_rid.values()
                 if s.status == "shed" and s.code == "deadline")
    assert n_aborted == 2 and n_shed == 2
    assert eng.pool.n_used == 0


def test_predicted_ttft_shed_gate(eng):
    eng.run([])
    # prime the EWMAs as if the engine were deeply backlogged: any deadline
    # tighter than one queue wave is unattainable
    eng._svc_s, eng._chunk_s = 5.0, 1.0
    try:
        for r in _reqs(3, vocab=32):
            eng.submit(r, now=0.0)                # fill both slots + queue
        eng.sched.admit(0.0)
        tight = Request(prompt=np.zeros(6, np.int32), max_new_tokens=4,
                        rid="tight", ttft_deadline_s=0.01)
        st = eng.submit(tight, now=0.0)
        assert st.status == "shed" and st.code == "ttft_unattainable"
        loose = Request(prompt=np.zeros(6, np.int32), max_new_tokens=4,
                        rid="loose", ttft_deadline_s=1e6)
        assert eng.submit(loose, now=0.0).status == "queued"
    finally:
        eng._svc_s = eng._chunk_s = 0.0
        eng.run([])                               # leave the engine clean


def test_cold_engine_never_ttft_sheds():
    # EWMAs start at zero -> _predict_ttft is None -> no shed on a fresh
    # engine regardless of deadline (checked without building an engine)
    assert ContinuousBatchingEngine._predict_ttft.__doc__  # documented
    class _Stub:
        _chunk_s = _svc_s = 0.0
    assert ContinuousBatchingEngine._predict_ttft(
        _Stub(), Request(prompt=np.zeros(4, np.int32), max_new_tokens=2,
                         ttft_deadline_s=1e-9)) is None


# ---------------------------------------------------------------------------
# auditor
# ---------------------------------------------------------------------------

def test_auditor_clean_run_counts_checks(eng, trace, clean_tokens):
    eng.auditor = EngineAuditor()
    try:
        report = eng.run(list(trace))
    finally:
        auditor, eng.auditor = eng.auditor, None
    assert auditor.n_checks > 0
    assert report["aggregate"]["audit_checks"] == auditor.n_checks
    # zero perturbation: the audited run's streams match the unaudited ones
    assert _tokens(report) == clean_tokens


def test_auditor_detects_injected_corruption(eng, trace):
    eng.run(list(trace))
    auditor = EngineAuditor()
    auditor.check(eng)                            # healthy engine: clean
    free = eng.pool.free_slots()[0] if hasattr(eng.pool, "free_slots") else 0
    eng.active[free] = True                       # active row, no owner
    try:
        with pytest.raises(AuditViolation) as exc:
            auditor.check(eng)
        assert exc.value.invariant == "active_mask"
    finally:
        eng.active[free] = False
    auditor.check(eng)                            # corruption repaired


def test_auditor_rate_limit():
    auditor = EngineAuditor(every=4)
    seen = []
    auditor.check = lambda engine: seen.append(engine)   # type: ignore
    for _ in range(8):
        auditor.maybe_check("e")
    assert len(seen) == 2


# ---------------------------------------------------------------------------
# chaos soak: randomized fault plans, full recovery contract per seed
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def chaos_ctx(eng, trace, clean_tokens):
    return eng, trace, clean_tokens


_chaos_ctx = {}


@pytest.fixture(scope="module", autouse=True)
def _bind_chaos_ctx(chaos_ctx):
    _chaos_ctx["ctx"] = chaos_ctx
    yield
    _chaos_ctx.clear()


@settings(max_examples=6, deadline=None)
@given(st.integers(min_value=0, max_value=2 ** 20))
def test_chaos_soak_random_plans(seed):
    """Any seeded FaultPlan over the shared trace must satisfy the recovery
    contract: only fired victims error, bystanders byte-identical, zero
    slot/source leaks, and an exact replay under ``plan.replay()``."""
    eng, trace, clean = _chaos_ctx["ctx"]
    plan = FaultPlan.random(seed, [r.rid for r in trace], n_faults=3)
    eng.faults = plan
    try:
        faulted = eng.run(list(trace))
        eng.faults = plan.replay()
        replayed = eng.run(list(trace))
    finally:
        eng.faults = None
    victims = sorted(plan.victims())
    assert _errored(faulted) == victims
    ft = _tokens(faulted)
    for rid, toks in clean.items():
        if rid in victims:
            assert ft[rid] == toks[:len(ft[rid])], (seed, rid)
        else:
            assert ft[rid] == toks, (seed, rid)
    assert _tokens(replayed) == ft and _errored(replayed) == victims
    assert eng.pool.n_used == 0
    assert faulted["aggregate"]["n_retired"] == len(trace) - len(victims)


# ---------------------------------------------------------------------------
# ingest faults need a source-bearing config (whisper reduced)
# ---------------------------------------------------------------------------

def test_ingest_fail_quarantines_before_device_write():
    cfg = get_config("whisper-small", reduced=True)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    e = ContinuousBatchingEngine(model, params, n_slots=2, max_len=64,
                                 chunk=8, decode_ticks=2, seed=0)
    e.warmup()
    trace = poisson_trace(n_requests=4, vocab_size=cfg.vocab_size,
                          prompt_len=(4, 8), max_new=(3, 5), seed=5,
                          source_len=(2, cfg.source_len),
                          source_dim=cfg.d_model)
    clean = e.run(list(trace))
    victim = trace[1].rid
    e.faults = FaultPlan([Fault("ingest_fail", rid=victim)])
    try:
        report = e.run(list(trace))
    finally:
        e.faults = None
    assert _errored(report) == [victim]
    rec = {r["rid"]: r for r in report["requests"]}[victim]
    assert rec["code"] == "source_ingest_failed" and rec["tokens"] == []
    for rid, toks in _tokens(clean).items():
        if rid != victim:
            assert _tokens(report)[rid] == toks
    assert e.pool.n_used == 0 and e.src_pool.n_used == 0
