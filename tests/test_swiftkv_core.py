"""SwiftKV recurrence (paper Eqs. 5-8): exactness vs two-pass softmax, the
branchy/fused equivalence, and the monoid-merge property that justifies the
blockwise kernel and the cross-device sequence-parallel decode."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ModuleNotFoundError:          # optional dep: seeded explicit cases
    from _hypothesis_compat import given, settings, st

from repro.core import swiftkv
from repro.core.swiftkv import (SwiftKVState, state_finalize, state_init,
                                state_merge, state_update_block)

jax.config.update("jax_platform_name", "cpu")


def _rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


@pytest.mark.parametrize("s,d", [(1, 8), (7, 16), (64, 32), (200, 64)])
def test_tokenwise_matches_softmax(s, d):
    q, k, v = _rand(0, d), _rand(1, s, d), _rand(2, s, d)
    got = swiftkv.swiftkv_decode_tokenwise(q, k, v)
    want = swiftkv.softmax_attention_reference(q, k, v)
    np.testing.assert_allclose(got, want, atol=1e-5)


@pytest.mark.parametrize("branchy", [True, False])
def test_branchy_and_fused_agree(branchy):
    """Eq. (6)/(7)'s two branches == the fused max-form rewrite."""
    q, k, v = _rand(0, 32), _rand(1, 50, 32), _rand(2, 50, 32)
    got = swiftkv.swiftkv_decode_tokenwise(q, k, v, branchy=branchy)
    want = swiftkv.softmax_attention_reference(q, k, v)
    np.testing.assert_allclose(got, want, atol=1e-5)


@pytest.mark.parametrize("block", [1, 3, 16, 64, 512])
def test_blockwise_matches_softmax_any_block(block):
    q, k, v = _rand(0, 16), _rand(1, 100, 16), _rand(2, 100, 16)
    got = swiftkv.swiftkv_decode_blockwise(q, k, v, block_size=block)
    want = swiftkv.softmax_attention_reference(q, k, v)
    np.testing.assert_allclose(got, want, atol=1e-5)


@pytest.mark.parametrize("length", [1, 33, 100])
def test_length_masking(length):
    q, k, v = _rand(0, 16), _rand(1, 100, 16), _rand(2, 100, 16)
    got = swiftkv.swiftkv_decode_blockwise(q, k, v, jnp.asarray(length),
                                           block_size=32)
    want = swiftkv.softmax_attention_reference(q, k[:length], v[:length])
    np.testing.assert_allclose(got, want, atol=1e-5)


@pytest.mark.parametrize("window", [1, 16, 99, 1000])
def test_sliding_window(window):
    q, k, v = _rand(0, 16), _rand(1, 128, 16), _rand(2, 128, 16)
    got = swiftkv.swiftkv_decode_blockwise(q, k, v, window=window,
                                           block_size=32)
    want = swiftkv.softmax_attention_reference(q, k, v, window=window)
    np.testing.assert_allclose(got, want, atol=1e-5)


def test_tokenwise_equals_blockwise_bitwise_structure():
    """Same math at different granularity: agree to fp tolerance."""
    q, k, v = _rand(0, 64), _rand(1, 300, 64), _rand(2, 300, 64)
    a = swiftkv.swiftkv_decode_tokenwise(q, k, v)
    b = swiftkv.swiftkv_decode_blockwise(q, k, v, block_size=128)
    np.testing.assert_allclose(a, b, atol=1e-5)


def test_sharded_reference_exact():
    q = _rand(0, 32)
    ks = [_rand(1, 64, 32), _rand(2, 64, 32), _rand(3, 64, 32)]
    vs = [_rand(4, 64, 32), _rand(5, 64, 32), _rand(6, 64, 32)]
    lens = [64, 64, 20]
    got = swiftkv.swiftkv_decode_sharded_reference(q, ks, vs, lens)
    k_all = jnp.concatenate([ks[0], ks[1], ks[2][:20]])
    v_all = jnp.concatenate([vs[0], vs[1], vs[2][:20]])
    want = swiftkv.softmax_attention_reference(q, k_all, v_all)
    np.testing.assert_allclose(got, want, atol=1e-5)


# ---------------------------------------------------------------------------
# Monoid properties (hypothesis): this is what licenses blockwise kernels and
# the cross-device merge — associativity, commutativity, identity.
# ---------------------------------------------------------------------------

def _mk_state(mu, z, y):
    return SwiftKVState(mu=jnp.float32(mu), z=jnp.float32(z),
                        y=jnp.asarray(y, jnp.float32))


finite = st.floats(min_value=-30, max_value=30, allow_nan=False,
                   allow_infinity=False)
pos = st.floats(min_value=1e-3, max_value=1e3, allow_nan=False)
vec3 = st.lists(finite, min_size=3, max_size=3)


@settings(max_examples=60, deadline=None)
@given(finite, pos, vec3, finite, pos, vec3)
def test_merge_commutative(m1, z1, y1, m2, z2, y2):
    a, b = _mk_state(m1, z1, y1), _mk_state(m2, z2, y2)
    ab, ba = state_merge(a, b), state_merge(b, a)
    np.testing.assert_allclose(ab.z, ba.z, rtol=1e-5)
    np.testing.assert_allclose(ab.y, ba.y, rtol=1e-5, atol=1e-5)


@settings(max_examples=60, deadline=None)
@given(finite, pos, vec3, finite, pos, vec3, finite, pos, vec3)
def test_merge_associative(m1, z1, y1, m2, z2, y2, m3, z3, y3):
    a, b, c = _mk_state(m1, z1, y1), _mk_state(m2, z2, y2), _mk_state(m3, z3, y3)
    left = state_merge(state_merge(a, b), c)
    right = state_merge(a, state_merge(b, c))
    np.testing.assert_allclose(left.z, right.z, rtol=1e-4)
    np.testing.assert_allclose(left.y, right.y, rtol=1e-4, atol=1e-4)


@settings(max_examples=30, deadline=None)
@given(finite, pos, vec3)
def test_merge_identity(m, z, y):
    a = _mk_state(m, z, y)
    e = state_init(3)  # (NEG_INF, 0, 0) is the monoid identity
    out = state_merge(a, e)
    # atol floor: XLA flushes f32 subnormals to zero under the 1.0x multiply
    np.testing.assert_allclose(out.z, a.z, rtol=1e-6, atol=1e-38)
    np.testing.assert_allclose(out.y, a.y, rtol=1e-6, atol=1e-38)
    out2 = state_merge(e, a)
    np.testing.assert_allclose(out2.z, a.z, rtol=1e-6, atol=1e-38)


@settings(max_examples=30, deadline=None)
@given(st.integers(min_value=1, max_value=6), st.integers(min_value=0, max_value=2**31 - 1))
def test_split_fold_equals_full_fold(n_splits, seed):
    """Folding a score stream in arbitrary split points + merging == one
    fold. This is the exact property the sequence-parallel decode relies on."""
    rng = np.random.default_rng(seed)
    s, d = 48, 8
    scores = jnp.asarray(rng.standard_normal((s,)), jnp.float32)
    vals = jnp.asarray(rng.standard_normal((s, d)), jnp.float32)
    ones = jnp.ones((s,), jnp.float32)

    full = state_update_block(state_init(d), scores, vals, ones)

    cuts = sorted(rng.choice(np.arange(1, s), size=n_splits - 1,
                             replace=False).tolist()) if n_splits > 1 else []
    bounds = [0, *cuts, s]
    acc = state_init(d)
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        part = state_update_block(state_init(d), scores[lo:hi], vals[lo:hi],
                                  ones[: hi - lo])
        acc = state_merge(acc, part)

    np.testing.assert_allclose(state_finalize(acc), state_finalize(full),
                               rtol=2e-5, atol=2e-5)


def test_alpha_beta_in_unit_interval():
    """The paper's hardware-friendliness claim: every exponential argument is
    <= 0, so alpha, beta lie in (0, 1]. Checked on a long random stream with
    the paper's initialization mu_1 = s_1 (Eq. 6/7 never see -inf)."""
    rng = np.random.default_rng(1)
    s = jnp.asarray(rng.standard_normal(500) * 10, jnp.float32)
    mu = float(s[0])                 # paper: mu_1 = s_1
    for t in range(1, 500):
        mu_new = max(mu, float(s[t]))
        alpha = np.exp(mu - mu_new)
        beta = np.exp(float(s[t]) - mu_new)
        assert 0 < alpha <= 1 and 0 < beta <= 1
        mu = mu_new
