"""Per-arch smoke (deliverable f): every assigned architecture instantiates a
REDUCED config of the same family and runs one forward + one train step on
CPU, asserting output shapes and no NaNs. The FULL configs are exercised only
via the dry-run (ShapeDtypeStruct, no allocation)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, ASSIGNED_ARCHS, get_config
from repro.models.api import build_model, lm_loss, needs_source
from repro.optim import adamw_init, adamw_update

B, S = 2, 16


def _batch(cfg):
    rng = jax.random.PRNGKey(1)
    toks = jax.random.randint(rng, (B, S + 1), 0, cfg.vocab_size)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if needs_source(cfg):
        batch["source"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.source_len, cfg.d_model),
            jnp.dtype(cfg.compute_dtype)) * 0.02
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finiteness(arch):
    cfg = get_config(arch, reduced=True)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    kw = ({"source": batch["source"]} if "source" in batch else {})
    logits, aux = model.forward(params, batch["tokens"], remat=False, **kw)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_one_train_step(arch):
    cfg = get_config(arch, reduced=True)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    batch = _batch(cfg)

    def loss_fn(p):
        return lm_loss(model, p, batch["tokens"], batch["labels"],
                       batch.get("source"), remat=False)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert bool(jnp.isfinite(loss))
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree_util.tree_leaves(grads)))
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0
    new_params, opt, metrics = adamw_update(params, grads, opt,
                                            lr=jnp.float32(1e-3))
    # params actually moved
    moved = max(float(jnp.max(jnp.abs(a - b)))
                for a, b in zip(jax.tree_util.tree_leaves(new_params),
                                jax.tree_util.tree_leaves(params)))
    assert moved > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch):
    """prefill + N decode steps produce the same last-position logits as one
    full forward — the cross-check that the KV cache, incremental RoPE
    (Eq. 11), and every family's recurrent state are all coherent."""
    cfg = get_config(arch, reduced=True)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    P_len, n_dec, MAX = 8, 3, 32
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, P_len + n_dec), 0,
                              cfg.vocab_size)
    src = None
    if needs_source(cfg):
        src = jax.random.normal(jax.random.PRNGKey(2),
                                (B, cfg.source_len, cfg.d_model)) * 0.02
    kw = {"source": src} if src is not None else {}
    full, _ = model.forward(params, toks, remat=False, **kw)
    want = full[:, -1, :]

    cache = model.init_cache(B, MAX, cfg.source_len if src is not None
                             else None)
    logits, cache = model.prefill(params, toks[:, :P_len], cache, src)
    for t in range(P_len, P_len + n_dec):
        logits, cache = model.decode_step(params, toks[:, t], cache)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(want),
                               atol=5e-5, rtol=1e-4)


@pytest.mark.parametrize("arch", ["gemma_2b", "hymba_1p5b", "rwkv6_3b"])
def test_rope_mode_direct_vs_incremental(arch):
    """Eq. 11 incremental RoPE == direct cos/sin recomputation at decode."""
    cfg = get_config(arch, reduced=True)
    if not cfg.rotary_dim:
        pytest.skip("no rotary")
    outs = {}
    for mode in ("incremental", "direct"):
        model = build_model(cfg.replace(rope_mode=mode))
        params = model.init_params(jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (B, 8), 0,
                                  cfg.vocab_size)
        cache = model.init_cache(B, 16, None)
        logits, cache = model.prefill(params, toks, cache)
        logits, _ = model.decode_step(params, jnp.ones((B,), jnp.int32),
                                      cache)
        outs[mode] = np.asarray(logits)
    np.testing.assert_allclose(outs["incremental"], outs["direct"],
                               atol=5e-5, rtol=1e-4)


def test_unroll_layers_equivalence():
    cfg = get_config("qwen3_8b", reduced=True)
    m1, m2 = build_model(cfg), build_model(cfg.replace(unroll_layers=True))
    params = m1.init_params(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    l1, _ = m1.forward(params, toks, remat=False)
    l2, _ = m2.forward(params, toks, remat=False)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-5)


def test_swa_limits_attention_reach():
    """h2o-danube SWA: a token far outside the window must not influence the
    decode logits."""
    cfg = get_config("h2o_danube_1p8b", reduced=True).replace(window=4)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0,
                              cfg.vocab_size)
    toks2 = toks.at[0, 0].set((toks[0, 0] + 1) % cfg.vocab_size)
    outs = []
    for t in (toks, toks2):
        cache = model.init_cache(1, 16, None)
        logits, cache = model.prefill(params, t, cache)
        logits, _ = model.decode_step(params, jnp.ones((1,), jnp.int32),
                                      cache)
        outs.append(np.asarray(logits))
    np.testing.assert_allclose(outs[0], outs[1], atol=1e-6)
