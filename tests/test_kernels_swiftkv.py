"""Pallas swiftkv_decode kernel: shape/dtype sweep vs the pure-jnp oracle
(interpret mode on CPU; identical code targets the TPU MXU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.swiftkv_decode import ops, ref

RNG = np.random.default_rng(7)


def mk(b, hq, hkv, s, d, dtype):
    q = jnp.asarray(RNG.standard_normal((b, hq, d)), dtype)
    k = jnp.asarray(RNG.standard_normal((b, s, hkv, d)), dtype)
    v = jnp.asarray(RNG.standard_normal((b, s, hkv, d)), dtype)
    lengths = jnp.asarray(RNG.integers(1, s + 1, (b,)), jnp.int32)
    return q, k, v, lengths


SWEEP = [
    # b, hq, hkv, s,    d,   block
    (1, 4, 4, 256, 64, 128),    # MHA
    (2, 8, 2, 512, 64, 128),    # GQA 4:1
    (2, 8, 1, 256, 128, 128),   # MQA
    (3, 4, 2, 384, 128, 128),   # non-pow2 batch/seq
    (1, 16, 8, 1024, 64, 256),  # wide
    (1, 2, 2, 128, 256, 128),   # big head_dim (gemma-style)
]


@pytest.mark.parametrize("b,hq,hkv,s,d,blk", SWEEP)
def test_kernel_vs_oracle_f32(b, hq, hkv, s, d, blk):
    q, k, v, lengths = mk(b, hq, hkv, s, d, jnp.float32)
    got = ops.swiftkv_decode(q, k, v, lengths, block_k=blk, interpret=True)
    want = ref.swiftkv_decode_ref(q, k, v, lengths)
    np.testing.assert_allclose(got, want, atol=2e-5)


@pytest.mark.parametrize("b,hq,hkv,s,d,blk", SWEEP[:3])
def test_kernel_vs_oracle_bf16(b, hq, hkv, s, d, blk):
    q, k, v, lengths = mk(b, hq, hkv, s, d, jnp.bfloat16)
    got = ops.swiftkv_decode(q, k, v, lengths, block_k=blk, interpret=True)
    want = ref.swiftkv_decode_ref(q, k, v, lengths)
    np.testing.assert_allclose(got.astype(jnp.float32),
                               want.astype(jnp.float32), atol=3e-2)


@pytest.mark.parametrize("window", [32, 100, 4096])
def test_kernel_sliding_window(window):
    q, k, v, lengths = mk(2, 4, 2, 512, 64, jnp.float32)
    got = ops.swiftkv_decode(q, k, v, lengths, block_k=128, window=window,
                             interpret=True)
    want = ref.swiftkv_decode_ref(q, k, v, lengths, window=window)
    np.testing.assert_allclose(got, want, atol=2e-5)


def test_kernel_lut_exp_mode():
    """exp_mode='lut' reproduces Eq. 9-10 inside the kernel; the error bound
    follows the paper's 0.00586% LUT error times the softmax conditioning."""
    q, k, v, lengths = mk(2, 4, 2, 256, 64, jnp.float32)
    got = ops.swiftkv_decode(q, k, v, lengths, block_k=128, exp_mode="lut",
                             interpret=True)
    want = ref.swiftkv_decode_ref(q, k, v, lengths)
    np.testing.assert_allclose(got, want, atol=5e-4)


def test_kernel_length_edge_cases():
    q, k, v, _ = mk(3, 4, 2, 256, 64, jnp.float32)
    for lens in ([1, 1, 1], [256, 256, 256], [1, 128, 256]):
        lengths = jnp.asarray(lens, jnp.int32)
        got = ops.swiftkv_decode(q, k, v, lengths, block_k=128,
                                 interpret=True)
        want = ref.swiftkv_decode_ref(q, k, v, lengths)
        np.testing.assert_allclose(got, want, atol=2e-5)


def test_kernel_block_k_snaps_to_divisor():
    """A non-dividing block_k request snaps down to the largest power-of-two
    divisor of S (640 = 5*128: 512 -> 128) — the cache still streams
    zero-copy in its native layout."""
    q, k, v, lengths = mk(2, 4, 2, 640, 64, jnp.float32)
    got = ops.swiftkv_decode(q, k, v, lengths, block_k=512, interpret=True)
    want = ref.swiftkv_decode_ref(q, k, v, lengths)
    np.testing.assert_allclose(got, want, atol=2e-5)


def test_kernel_small_cache_runs_unpadded():
    """S below one lane tile (64) uses block_k = S — no call-time pad."""
    q, k, v, lengths = mk(2, 4, 2, 64, 64, jnp.float32)
    got = ops.swiftkv_decode(q, k, v, lengths, block_k=512, interpret=True)
    want = ref.swiftkv_decode_ref(q, k, v, lengths)
    np.testing.assert_allclose(got, want, atol=2e-5)


def test_kernel_misaligned_cache_raises():
    """The zero-copy contract: a cache whose max_len admits no usable block
    size raises at trace time (allocate block-aligned at init_cache) instead
    of silently paying a whole-cache pad+copy per layer per decode step."""
    q, k, v, lengths = mk(2, 4, 2, 300, 64, jnp.float32)
    with pytest.raises(ValueError, match="block-aligned"):
        ops.swiftkv_decode(q, k, v, lengths, block_k=128, interpret=True)
