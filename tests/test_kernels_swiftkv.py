"""Pallas swiftkv_decode kernel: shape/dtype sweep vs the pure-jnp oracle
(interpret mode on CPU; identical code targets the TPU MXU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.swiftkv_decode import ops, ref

RNG = np.random.default_rng(7)


def mk(b, hq, hkv, s, d, dtype):
    q = jnp.asarray(RNG.standard_normal((b, hq, d)), dtype)
    k = jnp.asarray(RNG.standard_normal((b, s, hkv, d)), dtype)
    v = jnp.asarray(RNG.standard_normal((b, s, hkv, d)), dtype)
    lengths = jnp.asarray(RNG.integers(1, s + 1, (b,)), jnp.int32)
    return q, k, v, lengths


SWEEP = [
    # b, hq, hkv, s,    d,   block
    (1, 4, 4, 256, 64, 128),    # MHA
    (2, 8, 2, 512, 64, 128),    # GQA 4:1
    (2, 8, 1, 256, 128, 128),   # MQA
    (3, 4, 2, 384, 128, 128),   # non-pow2 batch/seq
    (1, 16, 8, 1024, 64, 256),  # wide
    (1, 2, 2, 128, 256, 128),   # big head_dim (gemma-style)
]


@pytest.mark.parametrize("b,hq,hkv,s,d,blk", SWEEP)
def test_kernel_vs_oracle_f32(b, hq, hkv, s, d, blk):
    q, k, v, lengths = mk(b, hq, hkv, s, d, jnp.float32)
    got = ops.swiftkv_decode(q, k, v, lengths, block_k=blk, interpret=True)
    want = ref.swiftkv_decode_ref(q, k, v, lengths)
    np.testing.assert_allclose(got, want, atol=2e-5)


@pytest.mark.parametrize("b,hq,hkv,s,d,blk", SWEEP[:3])
def test_kernel_vs_oracle_bf16(b, hq, hkv, s, d, blk):
    q, k, v, lengths = mk(b, hq, hkv, s, d, jnp.bfloat16)
    got = ops.swiftkv_decode(q, k, v, lengths, block_k=blk, interpret=True)
    want = ref.swiftkv_decode_ref(q, k, v, lengths)
    np.testing.assert_allclose(got.astype(jnp.float32),
                               want.astype(jnp.float32), atol=3e-2)


@pytest.mark.parametrize("window", [32, 100, 4096])
def test_kernel_sliding_window(window):
    q, k, v, lengths = mk(2, 4, 2, 512, 64, jnp.float32)
    got = ops.swiftkv_decode(q, k, v, lengths, block_k=128, window=window,
                             interpret=True)
    want = ref.swiftkv_decode_ref(q, k, v, lengths, window=window)
    np.testing.assert_allclose(got, want, atol=2e-5)


def test_kernel_lut_exp_mode():
    """exp_mode='lut' reproduces Eq. 9-10 inside the kernel; the error bound
    follows the paper's 0.00586% LUT error times the softmax conditioning."""
    q, k, v, lengths = mk(2, 4, 2, 256, 64, jnp.float32)
    got = ops.swiftkv_decode(q, k, v, lengths, block_k=128, exp_mode="lut",
                             interpret=True)
    want = ref.swiftkv_decode_ref(q, k, v, lengths)
    np.testing.assert_allclose(got, want, atol=5e-4)


def test_kernel_length_edge_cases():
    q, k, v, _ = mk(3, 4, 2, 256, 64, jnp.float32)
    for lens in ([1, 1, 1], [256, 256, 256], [1, 128, 256]):
        lengths = jnp.asarray(lens, jnp.int32)
        got = ops.swiftkv_decode(q, k, v, lengths, block_k=128,
                                 interpret=True)
        want = ref.swiftkv_decode_ref(q, k, v, lengths)
        np.testing.assert_allclose(got, want, atol=2e-5)


def test_kernel_block_k_snaps_to_divisor():
    """A non-dividing block_k request snaps down to the largest power-of-two
    divisor of S (640 = 5*128: 512 -> 128) — the cache still streams
    zero-copy in its native layout."""
    q, k, v, lengths = mk(2, 4, 2, 640, 64, jnp.float32)
    got = ops.swiftkv_decode(q, k, v, lengths, block_k=512, interpret=True)
    want = ref.swiftkv_decode_ref(q, k, v, lengths)
    np.testing.assert_allclose(got, want, atol=2e-5)


def test_kernel_small_cache_runs_unpadded():
    """S below one lane tile (64) uses block_k = S — no call-time pad."""
    q, k, v, lengths = mk(2, 4, 2, 64, 64, jnp.float32)
    got = ops.swiftkv_decode(q, k, v, lengths, block_k=512, interpret=True)
    want = ref.swiftkv_decode_ref(q, k, v, lengths)
    np.testing.assert_allclose(got, want, atol=2e-5)


def test_kernel_misaligned_cache_raises():
    """The zero-copy contract: a cache whose max_len admits no usable block
    size raises at trace time (allocate block-aligned at init_cache) instead
    of silently paying a whole-cache pad+copy per layer per decode step."""
    q, k, v, lengths = mk(2, 4, 2, 300, 64, jnp.float32)
    with pytest.raises(ValueError, match="block-aligned"):
        ops.swiftkv_decode(q, k, v, lengths, block_k=128, interpret=True)


# ---------------------------------------------------------------------------
# ring caches: rotated layouts consumed in place
# ---------------------------------------------------------------------------

RING = 256          # ring slots (= S fed to the kernel)
RWIN = 100          # SWA window


def _ringify(full: np.ndarray, lengths, r: int) -> jnp.ndarray:
    """Rotate a temporal cache into ring layout: slot s holds the newest
    position congruent to s mod r (zeros where that position is negative,
    i.e. before the row has written slot s)."""
    b, _, hkv, d = full.shape
    ring = np.zeros((b, r, hkv, d), full.dtype)
    for i in range(b):
        p = int(lengths[i]) - 1
        for s in range(r):
            pos = p - ((p - s) % r)
            if pos >= 0:
                ring[i, s] = full[i, pos]
    return jnp.asarray(ring)


# wrap offset: where (lengths mod RING) sits relative to the ring — exactly
# on the boundary, one past it, one short of a block edge, and mid-ring
@pytest.mark.parametrize("wrap_off", [0, 1, 127, 131])
def test_kernel_ring_rotated_cache(wrap_off):
    """The Pallas wrapper consumes a wrapped (rotated) ring cache in place
    and matches the temporal-layout oracle exactly — one wrapped row, one
    unwrapped row, one fresh row per batch."""
    b, hq, hkv, d = 3, 4, 2, 64
    lengths = np.asarray([2 * RING + wrap_off, RING - 37, 1], np.int32)
    L = int(lengths.max())
    kf = np.asarray(RNG.standard_normal((b, L, hkv, d)), np.float32)
    vf = np.asarray(RNG.standard_normal((b, L, hkv, d)), np.float32)
    q = jnp.asarray(RNG.standard_normal((b, hq, d)), jnp.float32)
    kr, vr = _ringify(kf, lengths, RING), _ringify(vf, lengths, RING)
    got = ops.swiftkv_decode(q, kr, vr, jnp.asarray(lengths), window=RWIN,
                             ring=True, block_k=128, interpret=True)
    want = ref.swiftkv_decode_ref(q, jnp.asarray(kf), jnp.asarray(vf),
                                  jnp.asarray(lengths), window=RWIN)
    np.testing.assert_allclose(got, want, atol=2e-5)


@pytest.mark.parametrize("wrap_off", [0, 1, 127, 131])
def test_blockwise_ring_rotated_cache(wrap_off):
    """swiftkv_decode_blockwise (the TPU-shaped reference the kernel
    mirrors) folds the same rotated ring to the same result through
    decode_attention's ring dispatch."""
    from repro.core import attention as attn
    b, hq, hkv, d = 2, 4, 2, 64
    lengths = np.asarray([2 * RING + wrap_off, RING + wrap_off // 2 + 7],
                         np.int32)
    L = int(lengths.max())
    kf = np.asarray(RNG.standard_normal((b, L, hkv, d)), np.float32)
    vf = np.asarray(RNG.standard_normal((b, L, hkv, d)), np.float32)
    q = jnp.asarray(RNG.standard_normal((b, hq, d)), jnp.float32)
    kr, vr = _ringify(kf, lengths, RING), _ringify(vf, lengths, RING)
    got = attn.decode_attention(q, kr, vr, jnp.asarray(lengths),
                                impl="blockwise", window=RWIN, ring=True,
                                block_size=128)
    want = ref.swiftkv_decode_ref(q, jnp.asarray(kf), jnp.asarray(vf),
                                  jnp.asarray(lengths), window=RWIN)
    np.testing.assert_allclose(got, want, atol=2e-5)


def _flat_primitives(jaxpr, acc: set) -> set:
    for eqn in jaxpr.eqns:
        acc.add(eqn.primitive.name)
        for v in eqn.params.values():
            vs = v if isinstance(v, (list, tuple)) else (v,)
            for x in vs:
                inner = getattr(x, "jaxpr", None)
                if inner is not None:
                    _flat_primitives(inner, acc)
                elif hasattr(x, "eqns"):
                    _flat_primitives(x, acc)
    return acc


def test_kernel_ring_consumed_zero_copy():
    """No silent unrotate: the lowered ring kernel program recovers slot
    positions arithmetically — it must contain no gather / roll / sort /
    scatter of the cache (a host-side unrotation would need one)."""
    q = jnp.zeros((2, 4, 64), jnp.float32)
    kr = jnp.zeros((2, RING, 2, 64), jnp.float32)
    lengths = jnp.asarray([2 * RING + 5, 40], jnp.int32)
    jaxpr = jax.make_jaxpr(
        lambda *a: ops.swiftkv_decode(*a, window=RWIN, ring=True,
                                      block_k=128, interpret=True))(
        q, kr, kr, lengths)
    prims = _flat_primitives(jaxpr.jaxpr, set())
    assert not prims & {"gather", "roll", "sort", "scatter",
                        "scatter-add", "rev"}, prims


def test_blockwise_ring_adds_no_data_movement():
    """The blockwise ring program must be the linear-cache program plus
    position *arithmetic* only: an unrotate would show up as new
    data-movement primitives (gather of the whole cache, roll, sort, ...)
    relative to the plain windowed decode on the same shapes."""
    from repro.core import attention as attn
    q = jnp.zeros((2, 4, 64), jnp.float32)
    kr = jnp.zeros((2, RING, 2, 64), jnp.float32)
    lengths = jnp.asarray([2 * RING + 5, 40], jnp.int32)

    def fn(ring):
        return jax.make_jaxpr(
            lambda *a: attn.decode_attention(*a, impl="blockwise",
                                             window=RWIN, ring=ring,
                                             block_size=128))(
            q, kr, kr, lengths)

    ring_prims = _flat_primitives(fn(True).jaxpr, set())
    linear_prims = _flat_primitives(fn(False).jaxpr, set())
    arithmetic = {"rem", "add", "sub", "mul", "sign", "select_n", "and",
                  "or", "not", "lt", "le", "gt", "ge", "eq", "ne",
                  "convert_element_type", "broadcast_in_dim", "iota",
                  "stop_gradient"}
    assert ring_prims - linear_prims <= arithmetic, \
        ring_prims - linear_prims


def test_ring_requires_window():
    q, k, v, lengths = mk(2, 4, 2, 256, 64, jnp.float32)
    with pytest.raises(ValueError, match="window"):
        ops.swiftkv_decode(q, k, v, lengths, ring=True, block_k=128,
                           interpret=True)


# ---------------------------------------------------------------------------
# int8 KV caches (+w4a8 serving): scale-plumbing parity vs the dequant oracle
# ---------------------------------------------------------------------------
# The int8 contract is *exact* relative to dequantize-then-attend: the scale
# multiply rides the block loads, so running the kernel on (int8 rows,
# scales) must equal running it on the dequantized f32 rows — float-order
# tolerance only, no quantization-error budget in these assertions.

from repro.core import attention as attn
from repro.core.quantization import dequantize_kv, quantize_kv


def _quant_cache(k):
    """[B, S, Hkv, D] f32 -> (int8 rows, scales [B, Hkv, S], dequant f32)."""
    q8, s = quantize_kv(k)                        # scale [B, S, Hkv]
    sc = jnp.transpose(s, (0, 2, 1))              # position-last plane
    return q8, sc, dequantize_kv(q8, s)


@pytest.mark.parametrize("b,hq,hkv,s,d,blk", SWEEP[:4])
def test_kernel_int8_vs_dequant_oracle(b, hq, hkv, s, d, blk):
    q, k, v, lengths = mk(b, hq, hkv, s, d, jnp.float32)
    k8, ks, kf = _quant_cache(k)
    v8, vs, vf = _quant_cache(v)
    got = ops.swiftkv_decode(q, k8, v8, lengths, block_k=blk,
                             k_scale=ks, v_scale=vs, interpret=True)
    want = ops.swiftkv_decode(q, kf, vf, lengths, block_k=blk,
                              interpret=True)
    np.testing.assert_allclose(got, want, atol=2e-5)


def test_blockwise_int8_vs_dequant_oracle():
    q, k, v, lengths = mk(2, 8, 2, 512, 64, jnp.float32)
    k8, ks, kf = _quant_cache(k)
    v8, vs, vf = _quant_cache(v)
    got = attn.decode_attention(q, k8, v8, lengths, impl="blockwise",
                                block_size=128, k_scale=ks, v_scale=vs)
    want = attn.decode_attention(q, kf, vf, lengths, impl="blockwise",
                                 block_size=128)
    np.testing.assert_allclose(got, want, atol=2e-5)


def test_naive_int8_agrees_with_blockwise_int8():
    """The dense oracle (dequantize up front) and the streaming scale
    multiply are the same math in different orders."""
    q, k, v, lengths = mk(2, 4, 2, 256, 64, jnp.float32)
    k8, ks, _ = _quant_cache(k)
    v8, vs, _ = _quant_cache(v)
    a = attn.decode_attention(q, k8, v8, lengths, impl="naive",
                              k_scale=ks, v_scale=vs)
    b_ = attn.decode_attention(q, k8, v8, lengths, impl="blockwise",
                               block_size=128, k_scale=ks, v_scale=vs)
    np.testing.assert_allclose(a, b_, atol=2e-5)


@pytest.mark.parametrize("wrap_off", [0, 1, 127, 131])
def test_kernel_int8_ring_wrap(wrap_off):
    """int8 ring cache at the wrap boundary offsets: the per-slot scale
    plane rides the same rotated layout as the rows (slot s's scale
    multiplies slot s's row, wherever its absolute position landed)."""
    b, hq, hkv, d = 3, 4, 2, 64
    lengths = np.asarray([2 * RING + wrap_off, RING - 37, 1], np.int32)
    L = int(lengths.max())
    kf = np.asarray(RNG.standard_normal((b, L, hkv, d)), np.float32)
    vf = np.asarray(RNG.standard_normal((b, L, hkv, d)), np.float32)
    q = jnp.asarray(RNG.standard_normal((b, hq, d)), jnp.float32)
    kr, vr = _ringify(kf, lengths, RING), _ringify(vf, lengths, RING)
    k8, ks, krf = _quant_cache(kr)
    v8, vs, vrf = _quant_cache(vr)
    got = ops.swiftkv_decode(q, k8, v8, jnp.asarray(lengths), window=RWIN,
                             ring=True, block_k=128, k_scale=ks, v_scale=vs,
                             interpret=True)
    want = ops.swiftkv_decode(q, krf, vrf, jnp.asarray(lengths), window=RWIN,
                              ring=True, block_k=128, interpret=True)
    np.testing.assert_allclose(got, want, atol=2e-5)


@pytest.mark.parametrize("wrap_off", [0, 1, 127, 131])
def test_blockwise_int8_ring_wrap(wrap_off):
    b, hq, hkv, d = 2, 4, 2, 64
    lengths = np.asarray([2 * RING + wrap_off, RING + 11], np.int32)
    L = int(lengths.max())
    kf = np.asarray(RNG.standard_normal((b, L, hkv, d)), np.float32)
    vf = np.asarray(RNG.standard_normal((b, L, hkv, d)), np.float32)
    q = jnp.asarray(RNG.standard_normal((b, hq, d)), jnp.float32)
    kr, vr = _ringify(kf, lengths, RING), _ringify(vf, lengths, RING)
    k8, ks, krf = _quant_cache(kr)
    v8, vs, vrf = _quant_cache(vr)
    got = attn.decode_attention(q, k8, v8, jnp.asarray(lengths),
                                impl="blockwise", window=RWIN, ring=True,
                                block_size=128, k_scale=ks, v_scale=vs)
    want = attn.decode_attention(q, krf, vrf, jnp.asarray(lengths),
                                 impl="blockwise", window=RWIN, ring=True,
                                 block_size=128)
    np.testing.assert_allclose(got, want, atol=2e-5)


def test_pooled_int8_heterogeneous_entries():
    """int8 source-KV pool: slots mapping to different entries with
    heterogeneous lengths — including a shared entry and a length-0 row —
    equal the dequantized-pool read exactly."""
    e, s_src, hkv, hq, d, b = 3, 192, 2, 4, 64, 4
    kp = np.asarray(RNG.standard_normal((e, s_src, hkv, d)), np.float32)
    vp = np.asarray(RNG.standard_normal((e, s_src, hkv, d)), np.float32)
    q = jnp.asarray(RNG.standard_normal((b, hq, d)), jnp.float32)
    entries = jnp.asarray([0, 2, 0, 1], jnp.int32)     # slots 0/2 share entry 0
    lengths = jnp.asarray([192, 57, 130, 0], jnp.int32)
    k8, ks, kf = _quant_cache(jnp.asarray(kp))         # scale [E, Hkv, S]
    v8, vs, vf = _quant_cache(jnp.asarray(vp))
    got = attn.decode_cross_attention(q, k8, v8, entries, lengths,
                                      impl="blockwise", block_size=64,
                                      k_scale=ks, v_scale=vs)
    want = attn.decode_cross_attention(q, kf, vf, entries, lengths,
                                       impl="blockwise", block_size=64)
    np.testing.assert_allclose(got, want, atol=2e-5)
    # the no-source row reads an exact zero either way
    np.testing.assert_array_equal(np.asarray(got)[3], np.zeros((hq, d)))


def test_kernel_int8_ring_consumed_zero_copy():
    """The int8 ring program must stay zero-copy: scales stream blockwise
    next to the rows — no gather / roll / sort materializing a dequantized
    or unrotated copy of the cache."""
    q = jnp.zeros((2, 4, 64), jnp.float32)
    kr = jnp.zeros((2, RING, 2, 64), jnp.int8)
    sc = jnp.zeros((2, 2, RING), jnp.float32)
    lengths = jnp.asarray([2 * RING + 5, 40], jnp.int32)
    jaxpr = jax.make_jaxpr(
        lambda q_, k_, s_, l_: ops.swiftkv_decode(
            q_, k_, k_, l_, window=RWIN, ring=True, block_k=128,
            k_scale=s_, v_scale=s_, interpret=True))(q, kr, sc, lengths)
    prims = _flat_primitives(jaxpr.jaxpr, set())
    assert not prims & {"gather", "roll", "sort", "scatter",
                        "scatter-add", "rev"}, prims


def test_int8_scales_require_both():
    q, k, v, lengths = mk(1, 4, 2, 256, 64, jnp.float32)
    sc = jnp.ones((1, 2, 256), jnp.float32)
    with pytest.raises(ValueError, match="both"):
        ops.swiftkv_decode(q, k, v, lengths, block_k=128, k_scale=sc,
                           interpret=True)
