"""Continuous-batching subsystem: slot-pool invariants, scheduler
conservation, post-EOS pad emission, and engine mechanics (EOS backfill,
capacity rejection, construction-time gates). The per-family equivalence
sweep — greedy continuous == per-request generation for every config
claiming ``supports_ragged_serving()``, including the ring-KV variants —
lives in the shared harness of ``test_serving_conformance.py``."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.api import build_model
from repro.serving import (ContinuousBatchingEngine, KVSlotPool, Request,
                           Scheduler, ServingEngine, SlotPoolError,
                           SourceKVPool, poisson_trace)
from repro.serving.continuous import _pct

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def dense_model():
    cfg = get_config("llama2-7b", reduced=True)   # f32, 2-layer dense
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


# ---------------------------------------------------------------------------
# slot pool
# ---------------------------------------------------------------------------

def test_slot_pool_alloc_release_reuse():
    pool = KVSlotPool(3, max_len=64)
    assert pool.capacity == 63 and pool.n_free == 3
    a = pool.alloc("r0")
    b = pool.alloc("r1")
    c = pool.alloc("r2")
    assert sorted([a, b, c]) == [0, 1, 2]
    assert pool.alloc("r3") is None               # exhausted
    pool.set_length(b, 17)
    assert pool.length(b) == 17 and pool.occupancy() == 1.0
    assert pool.release(b) == "r1"
    assert pool.length(b) == 0                    # reset-on-release
    assert pool.alloc("r3") == b                  # freed slot reused
    pool.assert_consistent()


def test_slot_pool_misuse_raises():
    pool = KVSlotPool(2, max_len=32)
    s = pool.alloc("r0")
    pool.release(s)
    with pytest.raises(SlotPoolError):
        pool.release(s)                           # double release
    with pytest.raises(SlotPoolError):
        pool.set_length(s, 4)                     # unowned slot
    s = pool.alloc("r1")
    with pytest.raises(SlotPoolError):
        pool.set_length(s, pool.capacity + 1)     # over capacity
    assert not pool.fits(pool.capacity + 1) and pool.fits(pool.capacity)


# ---------------------------------------------------------------------------
# source-KV pool (host ledger; device-side contract in the conformance suite)
# ---------------------------------------------------------------------------

def test_source_pool_refcounted_sharing():
    pool = SourceKVPool(2, src_max=16)
    e0, fresh = pool.acquire("img-a")
    assert fresh and pool.refcount(e0) == 1       # first holder ingests
    e1, fresh = pool.acquire("img-a")
    assert e1 == e0 and not fresh                 # second shares, no ingest
    assert pool.refcount(e0) == 2 and pool.total_shares == 1
    e2, fresh = pool.acquire("img-b")
    assert fresh and e2 != e0 and pool.n_free == 0
    assert pool.acquire("img-c") == (None, False)  # exhausted
    assert pool.release("img-a") is None          # one holder remains
    assert pool.entry_of("img-a") == e0           # still resident
    assert pool.release("img-a") == e0            # last holder -> zero me
    assert pool.entry_of("img-a") is None and pool.n_free == 1
    # freed entry is reusable under a new id; stats count both ingests
    e3, fresh = pool.acquire("img-d")
    assert fresh and e3 == e0 and pool.total_ingests == 3
    pool.assert_consistent()


def test_source_pool_misuse_and_fits():
    pool = SourceKVPool(1, src_max=8)
    with pytest.raises(SlotPoolError):
        pool.release("never-acquired")
    assert pool.fits(0) and pool.fits(8) and not pool.fits(9)
    with pytest.raises(SlotPoolError):
        SourceKVPool(0, src_max=8)


def test_slot_pool_reserves_parking_row():
    # the ragged decode step parks masked writes on the last cache row
    pool = KVSlotPool(2, max_len=64)
    assert pool.capacity == 63


# ---------------------------------------------------------------------------
# scheduler
# ---------------------------------------------------------------------------

def _req(rid, p=4, gen=3):
    return Request(prompt=np.arange(p, dtype=np.int32),
                   max_new_tokens=gen, rid=rid)


def test_scheduler_conservation_and_backfill():
    sched = Scheduler(KVSlotPool(2, max_len=64))
    states = [sched.submit(_req(i)) for i in range(5)]
    # over-budget request is rejected at submit, not queued
    rej = sched.submit(Request(prompt=np.zeros(60, np.int32),
                               max_new_tokens=10, rid="big"))
    assert rej.status == "rejected" and len(sched.rejected) == 1

    retired = []
    now = 0.0
    while sched.pending():
        sched.admit(now)
        assert sched.pool.n_used <= 2
        for st in list(sched.prefilling):
            st.prefilled = len(st.request.prompt)
            sched.start_decoding(st)
        # retire one per tick: freed slot must backfill next tick
        slot, st = next(iter(sched.decoding.items()))
        sched.retire(st, "max_tokens", now)
        retired.append(st.rid)
        sched.assert_conservation()
        now += 1.0

    assert sorted(retired) == [0, 1, 2, 3, 4]      # each retires exactly once
    assert sched.n_admitted == sched.n_retired == 5
    assert sched.pool.n_free == 2                  # no slot leaks
    sched.assert_conservation()


def test_scheduler_fifo_admission():
    sched = Scheduler(KVSlotPool(1, max_len=64))
    for i in range(3):
        sched.submit(_req(i))
    order = []
    while sched.pending():
        sched.admit(0.0)
        for st in list(sched.prefilling):
            st.prefilled = len(st.request.prompt)
            sched.start_decoding(st)
            order.append(st.rid)
        slot, st = next(iter(sched.decoding.items()))
        sched.retire(st, "max_tokens", 0.0)
    assert order == [0, 1, 2]


# ---------------------------------------------------------------------------
# lock-step engine: post-EOS pad emission (reclaimable rows)
# ---------------------------------------------------------------------------

def test_lockstep_post_eos_emits_pad(dense_model):
    cfg, model, params = dense_model
    prompts = jax.random.randint(jax.random.PRNGKey(3), (2, 8), 0,
                                 cfg.vocab_size, jnp.int32)
    eng = ServingEngine(model, params, max_len=32, batch=2)
    free = np.asarray(eng.generate(prompts, steps=6))
    # re-run with eos = row 0's second token: row 0 emits up to (and
    # including) the EOS, then pads; row 1 is unaffected
    eos = int(free[0, 1])
    pad = cfg.vocab_size  # out-of-vocab pad id
    out = np.asarray(eng.generate(prompts, steps=6, eos_id=eos, pad_id=pad))
    row = out[0].tolist()
    stop = row.index(eos)
    assert row[:stop + 1] == free[0, :stop + 1].tolist()
    assert all(t == pad for t in row[stop + 1:])
    if eos not in free[1].tolist():
        assert out[1].tolist() == free[1].tolist()


# ---------------------------------------------------------------------------
# continuous engine: end-to-end mechanics (the per-family equivalence sweep
# lives in test_serving_conformance.py)
# ---------------------------------------------------------------------------

def test_continuous_eos_retires_early_and_backfills(dense_model):
    cfg, model, params = dense_model
    prompt = np.arange(5, dtype=np.int32)
    # find what the model greedily emits, then use its 2nd token as EOS
    probe = ContinuousBatchingEngine(model, params, n_slots=1, max_len=64,
                                     chunk=8)
    free = probe.run([Request(prompt=prompt, max_new_tokens=8, rid="probe")])
    toks = free["requests"][0]["tokens"]
    eos = toks[1]

    eng = ContinuousBatchingEngine(model, params, n_slots=1, max_len=64,
                                   chunk=8, eos_id=eos)
    # a second queued request must backfill the slot freed by the EOS
    report = eng.run([Request(prompt=prompt, max_new_tokens=8, rid="a"),
                      Request(prompt=prompt + 1, max_new_tokens=3, rid="b")])
    by_rid = {r["rid"]: r for r in report["requests"]}
    assert by_rid["a"]["tokens"] == toks[:2]      # EOS emitted, then retired
    assert by_rid["a"]["finish_reason"] == "eos"
    assert by_rid["b"]["n_tokens"] >= 1
    assert eng.pool.n_free == 1


def test_continuous_respects_slot_capacity(dense_model):
    cfg, model, params = dense_model
    eng = ContinuousBatchingEngine(model, params, n_slots=2, max_len=32,
                                   chunk=8)
    st = eng.submit(Request(prompt=np.zeros(30, np.int32),
                            max_new_tokens=8, rid="big"))
    assert st.status == "rejected"                 # 38 rows > capacity 31


def test_continuous_construction_gate_is_empty():
    """No family is gated from continuous batching any more. Ring KV caches
    construct (per-slot write-mask parking, O(window) rows), and
    cross-attention stacks construct too: their encoder-side K/V lives in
    the source-KV pool (``cache['src_k'|'src_v'|'src_len'|'src_index']``),
    keyed by source id on the host side. test_serving_conformance.py runs
    the full equivalence harness over every config."""
    cfg = get_config("h2o-danube-1.8b+ring", reduced=True)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    eng = ContinuousBatchingEngine(model, params, n_slots=2, max_len=256,
                                   chunk=8)                # constructs fine
    assert eng.cache["k"].shape[2] == 128 < 256            # O(window) rows
    # audio (encoder-decoder): source-KV pool allocated, one entry per slot
    wcfg = get_config("whisper-small", reduced=True)
    wmodel = build_model(wcfg)
    wparams = wmodel.init_params(jax.random.PRNGKey(0))
    weng = ContinuousBatchingEngine(wmodel, wparams, n_slots=2, max_len=32,
                                    chunk=8)
    assert weng.src_pool is not None and weng.src_pool.n_entries == 2
    assert weng.cache["src_k"].shape[:3] == (wcfg.n_layers, 2,
                                             wcfg.source_len)
    assert weng.cache["src_index"].shape == (2,)


def test_continuous_rejects_oversized_source():
    """A source longer than the source-KV pool rows is rejected at submit
    (same graceful path as a prompt exceeding slot capacity), not
    discovered as an ingest-time shape error."""
    wcfg = get_config("whisper-small", reduced=True)
    wmodel = build_model(wcfg)
    wparams = wmodel.init_params(jax.random.PRNGKey(0))
    eng = ContinuousBatchingEngine(wmodel, wparams, n_slots=1, max_len=32,
                                   chunk=8)
    big = np.zeros((wcfg.source_len + 1, wcfg.d_model), np.float32)
    st = eng.submit(Request(prompt=np.zeros(4, np.int32), max_new_tokens=2,
                            rid="big-src", source=big))
    assert st.status == "rejected" and "source" in st.finish_reason
    # a shared source id with no features would poison the pool entry
    # (src_len 0) for every later holder of the same id — rejected up front
    st = eng.submit(Request(prompt=np.zeros(4, np.int32), max_new_tokens=2,
                            rid="id-no-src", source_id="img-1"))
    assert st.status == "rejected" and "source_id" in st.finish_reason


def test_fused_sampler_seeded_reproducible(dense_model):
    """temperature > 0 sampling runs on device (per-slot Gumbel-max keyed on
    (seed, request admission serial, token index)): a fixed (seed, trace)
    replays token-for-token — even with timed arrivals, where the wall clock
    changes how prefill chunks and decode ticks interleave — and a different
    seed draws a different stream."""
    cfg, model, params = dense_model
    # rate > 0: requests arrive over ~50 ms, so interleaving varies run to
    # run while the sampled tokens must not
    trace = poisson_trace(n_requests=5, vocab_size=cfg.vocab_size,
                          prompt_len=(3, 18), max_new=(4, 10), seed=3,
                          rate=100.0)

    def run(seed):
        eng = ContinuousBatchingEngine(model, params, n_slots=2, max_len=64,
                                       chunk=8, temperature=0.8, seed=seed)
        eng.warmup()     # warmup must not perturb the sampled stream
        rep = eng.run(list(trace))
        return {r["rid"]: r["tokens"] for r in rep["requests"]}

    first = run(7)
    assert run(7) == first
    assert run(8) != first


def test_report_pct_nearest_rank():
    assert _pct([], 0.5) is None
    assert _pct([1.0, 2.0], 0.50) == 1.0     # p50 of 2 is the lower element
    assert _pct([1.0, 2.0], 0.95) == 2.0
    assert _pct([1.0, 2.0, 3.0], 0.50) == 2.0
    xs = [float(i) for i in range(1, 101)]
    assert _pct(xs, 0.50) == 50.0            # ceil(.5*100)-1 -> index 49
    assert _pct(xs, 0.95) == 95.0
    assert _pct(xs, 1.00) == 100.0
    assert _pct([4.2], 0.95) == 4.2


def test_continuous_chunk_must_divide_max_len(dense_model):
    cfg, model, params = dense_model
    with pytest.raises(ValueError):
        ContinuousBatchingEngine(model, params, n_slots=2, max_len=64,
                                 chunk=7)
