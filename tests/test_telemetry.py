"""Serving telemetry: event-stream invariants against the live engine,
log-bucket histogram accuracy vs the exact nearest-rank reference, the
source-KV pool ledger, and Perfetto trace-export validity.

The contracts pinned here are the ones the observability layer sells:

* **disabled == absent** — an engine with ``telemetry=None`` produces
  byte-identical tokens to one that never heard of telemetry, and records
  zero events;
* **events agree with report()** — the stream is not a parallel accounting
  system: per-kind event counts equal the engine's own counters exactly;
* **per-request ordering** — enqueue <= admit < first_token < retire <=
  release on the engine clock, for every request;
* **histogram accuracy** — ``LogHistogram.percentile`` lands within one
  log bucket (a factor of ``10**(1/bpd)``) of ``_pct``'s exact
  nearest-rank value, and merged histograms match a single combined one;
* **export validity** — the Chrome trace JSON round-trips, uses one pid,
  maps slot ``s`` to tid ``s + 1`` stably, and carries every lifecycle
  event of every request.
"""
from __future__ import annotations

import json
import math
import random

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.api import build_model
from repro.serving import (ContinuousBatchingEngine, LogHistogram,
                           SourceKVPool, Telemetry, load_events_jsonl,
                           poisson_trace)
from repro.serving.continuous import _pct
from repro.serving.telemetry import EVENT_KINDS, LIFECYCLE_KINDS
from repro.serving.trace import PID, SCHED_TID, chrome_trace, slot_tid

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# LogHistogram
# ---------------------------------------------------------------------------

def test_histogram_percentile_within_one_bucket_of_exact():
    rng = random.Random(7)
    hist = LogHistogram()                       # defaults: 1e-6..1e4, bpd 16
    xs = [rng.lognormvariate(mu, 1.0) for mu in (-6, -3, 0) for _ in range(67)]
    for x in xs:
        hist.add(x)
    xs.sort()
    g = 10 ** (1 / hist.bpd)
    for q in (0.05, 0.25, 0.50, 0.90, 0.95, 0.99, 1.0):
        exact = _pct(xs, q)
        approx = hist.percentile(q)
        # the bucket's geometric midpoint is within sqrt(g) of any sample
        # in the bucket; "within one bucket" allows a full factor of g
        assert exact / g <= approx <= exact * g, (q, exact, approx)


def test_histogram_merge_equals_combined():
    rng = random.Random(11)
    a, b, both = LogHistogram(), LogHistogram(), LogHistogram()
    for i in range(500):
        x = rng.expovariate(1.0) + 1e-4
        (a if i % 2 else b).add(x)
        both.add(x)
    a.merge(b)
    assert a.counts == both.counts and a.n == both.n == 500
    for q in (0.5, 0.95):
        assert a.percentile(q) == both.percentile(q)


def test_histogram_merge_rejects_different_bounds():
    with pytest.raises(ValueError):
        LogHistogram().merge(LogHistogram(buckets_per_decade=8))


def test_histogram_edges_and_clamping():
    hist = LogHistogram(lo=1e-3, hi=1e3, buckets_per_decade=4)
    assert hist.percentile(0.5) is None          # empty
    hist.add(0.0)                                # below lo -> bucket 0
    hist.add(1e9)                                # above hi -> last bucket
    assert hist.counts[0] == 1 and hist.counts[-1] == 1
    lo_edge, _ = hist.edges(0)
    assert math.isclose(lo_edge, 1e-3)
    hist.reset()
    assert hist.n == 0 and sum(hist.counts) == 0


# ---------------------------------------------------------------------------
# Telemetry sink
# ---------------------------------------------------------------------------

def test_emit_rejects_unknown_kind():
    tel = Telemetry()
    with pytest.raises(ValueError):
        tel.emit("made_up_kind", t=0.0)
    assert set(LIFECYCLE_KINDS) < EVENT_KINDS   # gauges rides on top


def test_jsonl_stream_roundtrip(tmp_path):
    path = tmp_path / "events.jsonl"
    with Telemetry(jsonl_path=path) as tel:
        tel.emit("enqueue", t=0.25, rid="r0", queue_depth=1)
        tel.emit("admit", t=0.5, rid="r0", slot=2, serial=3)
        tel.emit("gauges", t=1.0, block=0, occupancy=0.5)
    back = load_events_jsonl(path)
    assert [e.kind for e in back] == ["enqueue", "admit", "gauges"]
    assert back[1].slot == 2 and back[1].serial == 3
    assert back[0].data == {"queue_depth": 1}
    assert back[2].data == {"occupancy": 0.5}
    # reset truncates the sink so file == in-memory stream
    tel.reset()
    assert path.read_text() == "" and tel.events == []


# ---------------------------------------------------------------------------
# engine integration: one traced run vs one untouched run, same workload
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def traced_run():
    cfg = get_config("llama2-7b", reduced=True)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    trace = poisson_trace(n_requests=8, vocab_size=cfg.vocab_size,
                          prompt_len=(4, 24), max_new=(4, 40), seed=3)

    def run(telemetry, ticks=8):
        eng = ContinuousBatchingEngine(
            model, params, n_slots=3, max_len=128, chunk=16,
            decode_ticks=ticks, seed=0, telemetry=telemetry)
        eng.warmup()
        report = eng.run(trace)["aggregate"]
        tokens = {r.request.rid: list(r.tokens) for r in eng.sched.retired}
        return report, tokens

    tel = Telemetry()
    report_on, tokens_on = run(tel)
    report_off, tokens_off = run(None)
    return tel, report_on, tokens_on, report_off, tokens_off


def test_disabled_identical_tokens_and_no_events(traced_run):
    tel, report_on, tokens_on, report_off, tokens_off = traced_run
    assert tokens_on == tokens_off               # telemetry never perturbs
    assert report_off.get("telemetry_events") is None
    assert report_on["telemetry_events"] == len(tel.events) > 0


def test_event_counts_match_report_counters(traced_run):
    tel, report, *_ = traced_run
    counts = tel.counts()
    n = report["n_retired"]
    assert report["n_requests"] == 8 and report["n_rejected"] == 0
    assert counts["enqueue"] == 8
    assert counts["admit"] == n
    assert counts["first_token"] == n
    assert counts["release"] == n
    assert counts["eos"] + counts["budget_retire"] == n
    assert counts["decode_block"] == report["decode_dispatches"]
    assert counts["gauges"] == report["decode_dispatches"]
    assert counts["prefill_chunk"] == report["prefill_chunks"]
    # 3 slots, 8 retirements: at least 5 admissions reuse a freed slot
    assert counts["backfill"] >= n - 3
    assert counts["reject"] == 0
    assert sum(counts.values()) == len(tel.events)


def test_per_request_event_ordering(traced_run):
    tel, report, tokens_on, *_ = traced_run
    rids = set(tokens_on)
    for rid in rids:
        evs = tel.by_rid(rid)
        by_kind = {}
        for ev in evs:
            by_kind.setdefault(ev.kind, []).append(ev)
        for kind in ("enqueue", "admit", "first_token", "release"):
            assert len(by_kind[kind]) == 1, (rid, kind)
        enqueue = by_kind["enqueue"][0]
        admit = by_kind["admit"][0]
        tok0 = by_kind["first_token"][0]
        release = by_kind["release"][0]
        retire = (by_kind.get("eos") or by_kind["budget_retire"])[0]
        assert enqueue.t <= admit.t < tok0.t < retire.t <= release.t, rid
        # slot/serial agree across the request's slot-bound events
        assert admit.slot == tok0.slot == retire.slot == release.slot
        assert tok0.serial == retire.serial == release.serial
        # prefill chunks sit between admit and first token, in block order
        chunks = by_kind["prefill_chunk"]
        assert chunks and all(admit.t <= c.t <= tok0.t for c in chunks)
        offs = [c.data["offset"] for c in chunks]
        assert offs == sorted(offs)


def test_parked_ticks_accounting(traced_run):
    tel, report, *_ = traced_run
    blocks = tel.by_kind("decode_block")
    issued = sum(b.data["k"] * len(b.data["slots"]) for b in blocks)
    emitted = sum(b.data["emitted"] for b in blocks)
    parked = sum(b.data["parked"] for b in blocks)
    assert issued == report["issued_ticks"]
    assert parked == report["parked_ticks"] == issued - emitted
    # every generated token is either a prefill first-token or a decode tick
    assert emitted + report["n_retired"] == report["generated_tokens"]
    # no eos_id on this run: the adaptive horizon clamps K to the minimum
    # remaining budget, so budget retirement always lands on a block
    # boundary and nothing is stranded — parking is an EOS-only cost
    assert parked == 0
    # per-block slot attribution is self-consistent
    for b in blocks:
        assert sum(b.data["tokens_per_slot"]) == b.data["emitted"]
        assert all(0 <= n <= b.data["k"] for n in b.data["tokens_per_slot"])


def test_parked_ticks_from_mid_block_eos(traced_run):
    # force a retirement the horizon cannot predict: pick a token that the
    # no-eos run emitted mid-stream and rerun with it as eos_id — the
    # request now retires inside a block, stranding the rest of its ticks
    _, _, tokens_on, *_ = traced_run
    longest = max(tokens_on.values(), key=len)
    eos_id = longest[len(longest) // 2]

    cfg = get_config("llama2-7b", reduced=True)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    trace = poisson_trace(n_requests=8, vocab_size=cfg.vocab_size,
                          prompt_len=(4, 24), max_new=(4, 40), seed=3)
    tel = Telemetry()
    eng = ContinuousBatchingEngine(model, params, n_slots=3, max_len=128,
                                   chunk=16, decode_ticks=8, seed=0,
                                   eos_id=eos_id, telemetry=tel)
    eng.warmup()
    rep = eng.run(trace)["aggregate"]
    assert len(tel.by_kind("eos")) >= 1
    assert rep["parked_ticks"] > 0
    blocks = tel.by_kind("decode_block")
    assert sum(b.data["parked"] for b in blocks) == rep["parked_ticks"]


def test_gauges_payload(traced_run):
    tel, report, *_ = traced_run
    gauges = tel.by_kind("gauges")
    assert gauges
    for g in gauges:
        d = g.data
        assert 0 <= d["active_slots"] <= 3
        assert d["active_slots"] + d["free_slots"] + d["prefilling"] == 3
        assert 0.0 <= d["occupancy"] <= 1.0
        assert d["tick_k"] >= 1 and d["queue_depth"] >= 0
        assert d["kv_bytes_live"] >= 0
        assert d["parked_ticks_block"] >= 0
    assert gauges[-1].data["parked_ticks_total"] == report["parked_ticks"]


def test_itl_source_labels(traced_run):
    _, report_on, *_ = traced_run
    assert report_on["itl_source"] == "subdivided"     # decode_ticks == 8
    cfg = get_config("llama2-7b", reduced=True)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    eng = ContinuousBatchingEngine(model, params, n_slots=2, max_len=64,
                                   chunk=16, decode_ticks=1, seed=0)
    eng.warmup()
    rep = eng.run(poisson_trace(n_requests=3, vocab_size=cfg.vocab_size,
                                prompt_len=(4, 8), max_new=(4, 8),
                                seed=1))["aggregate"]
    assert rep["itl_source"] == "exact"
    assert rep["parked_ticks"] == 0                    # K=1 cannot strand


# ---------------------------------------------------------------------------
# Perfetto export
# ---------------------------------------------------------------------------

def test_chrome_trace_valid_and_complete(traced_run, tmp_path):
    tel, report, tokens_on, *_ = traced_run
    path = tel.write_chrome_trace(tmp_path / "run.trace.json")
    doc = json.loads(path.read_text())                 # valid JSON on disk
    evs = doc["traceEvents"]
    assert doc["displayTimeUnit"] == "ms" and evs

    assert all(e["pid"] == PID for e in evs)           # one engine process
    # slot s always renders on tid s+1; scheduler lane is tid 0
    names = {(e["tid"], e["args"]["name"]) for e in evs if e["ph"] == "M"
             and e["name"] == "thread_name"}
    assert (SCHED_TID, "scheduler") in names
    for slot in range(3):
        assert (slot_tid(slot), f"slot {slot}") in names
    for src, out in zip(tel.events, [e for e in evs if e["ph"] != "M"]):
        pass  # ordering preserved is checked by the instant-mark scan below

    # every lifecycle event of every request appears in the export
    instants = [e for e in evs if e["ph"] == "i"]
    slices = [e for e in evs if e["ph"] == "X"]
    for rid in tokens_on:
        for kind in ("enqueue", "admit", "first_token"):
            assert any(e["name"] == kind and e["args"].get("rid") == rid
                       for e in instants), (rid, kind)
        assert any(e["name"] in ("eos", "budget_retire")
                   and e["args"].get("rid") == rid for e in instants), rid
        assert any(e["name"] == "release" and e["args"].get("rid") == rid
                   for e in instants), rid
        assert any(e["name"] == "prefill_chunk"
                   and e["args"].get("rid") == rid for e in slices), rid
    assert sum(e["name"].startswith("decode_block") for e in slices) == \
        sum(len(b.data["slots"]) for b in tel.by_kind("decode_block"))
    # gauge counter tracks present
    counter_names = {e["name"] for e in evs if e["ph"] == "C"}
    assert {"occupancy", "queue_depth", "tick_k"} <= counter_names
    # slot-bound instants land on their slot's lane
    for e in instants:
        slot = next((ev.slot for ev in tel.events
                     if ev.kind == e["name"]
                     and ev.rid == e["args"].get("rid")), None)
        if slot is not None:
            assert e["tid"] == slot_tid(slot)


def test_chrome_trace_deterministic(traced_run):
    tel, *_ = traced_run
    assert chrome_trace(tel.events) == chrome_trace(tel.events)


# ---------------------------------------------------------------------------
# source-KV pool ledger
# ---------------------------------------------------------------------------

def test_source_pool_ledger_events():
    seen = []

    def sink(kind, **data):
        seen.append((kind, dict(data)))

    pool = SourceKVPool(2, src_max=8, on_event=sink)
    e0, fresh = pool.acquire("srcA", owner="r0")
    assert fresh
    assert seen[-1][0] == "source_ingest"
    assert seen[-1][1]["source_id"] == "srcA"
    assert seen[-1][1]["entry"] == e0 and seen[-1][1]["refcount"] == 1
    assert seen[-1][1]["rid"] == "r0"

    e1, fresh = pool.acquire("srcA", owner="r1")    # refcount share
    assert e1 == e0 and not fresh
    assert seen[-1] == ("source_share", {"rid": "r1", "entry": e0,
                                         "source_id": "srcA", "refcount": 2})

    pool.release("srcA", owner="r0")                # still held by r1
    assert seen[-1][0] == "source_share"            # no release event yet
    pool.release("srcA", owner="r1")                # last holder
    assert seen[-1] == ("source_release", {"rid": "r1", "entry": e0,
                                           "source_id": "srcA",
                                           "refcount": 0})
    kinds = [k for k, _ in seen]
    assert kinds == ["source_ingest", "source_share", "source_release"]


def test_source_pool_silent_without_sink():
    pool = SourceKVPool(1, src_max=4)               # on_event=None: no-op
    e, fresh = pool.acquire("s", owner="r")
    assert fresh
    pool.release("s", owner="r")
    assert pool.refcount(e) == 0
