"""Beyond-paper perf features: ring KV cache, sequence-parallel decode via
the DistContext, sequence-sharded residuals, remat policies — correctness
(not speed) on CPU."""
from __future__ import annotations

import json
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models.api import build_model


def _greedy_logits(cfg, prompt_len=24, steps=6, max_len=128):
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, prompt_len), 0,
                              cfg.vocab_size)
    cache = model.init_cache(2, max_len, None)
    logits, cache = model.prefill(params, toks, cache)
    out = [logits]
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for _ in range(steps):
        logits, cache = model.decode_step(params, tok, cache)
        out.append(logits)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    return jnp.stack(out)


def test_ring_cache_equals_full_window_decode():
    """Ring KV cache (kv_ring) must reproduce the full-cache SWA decode
    bit-for-bit up to fp tolerance, including prompts longer than the ring."""
    cfg = get_config("h2o_danube_1p8b", reduced=True)   # window = 32
    full = _greedy_logits(cfg)
    ring = _greedy_logits(cfg.replace(kv_ring=True))
    np.testing.assert_allclose(np.asarray(full), np.asarray(ring), atol=1e-4)


def test_ring_cache_is_small():
    cfg = get_config("h2o_danube_1p8b", reduced=True).replace(kv_ring=True)
    model = build_model(cfg)
    cache = model.init_cache(2, 4096, None)
    assert cache["k"].shape[2] == 128  # ~window slots, not 4096


def test_sp_impl_falls_back_without_mesh():
    """decode_impl='sp' outside a mesh context must silently use blockwise."""
    cfg = get_config("qwen3_8b", reduced=True)
    base = _greedy_logits(cfg)
    sp = _greedy_logits(cfg.replace(decode_impl="sp"))
    np.testing.assert_allclose(np.asarray(base), np.asarray(sp), atol=1e-4)


_SP_CTX_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import warnings; warnings.filterwarnings("ignore")
import jax, jax.numpy as jnp, numpy as np, json
from repro.distributed.context import set_context
from repro.core import attention as attn

mesh = jax.make_mesh((4, 2), ("data", "model"))
set_context(mesh, batch_axes=("data",), model_axis="model")
rng = np.random.default_rng(0)
b, hq, hkv, s, d = 4, 4, 2, 256, 32
q = jnp.asarray(rng.standard_normal((b, hq, d)), jnp.float32)
k = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
v = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
lengths = jnp.asarray([256, 100, 17, 200], jnp.int32)
with mesh:
    got = jax.jit(lambda *a: attn.decode_attention(*a, impl="sp"))(
        q, k, v, lengths)
want = attn.decode_attention(q, k, v, lengths, impl="naive")
print(json.dumps({"err": float(jnp.max(jnp.abs(got - want)))}))
"""


@pytest.mark.slow
def test_sp_decode_through_context_multidevice():
    proc = subprocess.run([sys.executable, "-c", _SP_CTX_SCRIPT],
                          capture_output=True, text=True, timeout=300,
                          env={**__import__("os").environ,
                               "PYTHONPATH": "src"},
                          cwd=Path(__file__).resolve().parent.parent)
    assert proc.returncode == 0, proc.stderr[-2000:]
    err = json.loads(proc.stdout.strip().splitlines()[-1])["err"]
    assert err < 5e-6, err


@pytest.mark.parametrize("policy", ["full", "dots"])
def test_remat_policy_gradients_match(policy):
    """Both remat policies compute identical losses and gradients."""
    from repro.models.api import lm_loss
    cfg = get_config("qwen3_8b", reduced=True).replace(remat_policy=policy)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 17), 0,
                              cfg.vocab_size)
    loss, grads = jax.value_and_grad(
        lambda p: lm_loss(model, p, toks[:, :-1], toks[:, 1:], remat=True))(
        params)
    # compare against the no-remat reference
    loss0, grads0 = jax.value_and_grad(
        lambda p: lm_loss(model, p, toks[:, :-1], toks[:, 1:], remat=False))(
        params)
    assert float(loss) == pytest.approx(float(loss0), rel=1e-5)
    for g, g0 in zip(jax.tree_util.tree_leaves(grads),
                     jax.tree_util.tree_leaves(grads0)):
        np.testing.assert_allclose(np.asarray(g), np.asarray(g0), atol=1e-4,
                                   rtol=1e-3)


def test_seq_shard_noop_on_single_device():
    """_seq_shard is a no-op without a mesh (forward values unchanged)."""
    cfg = get_config("gemma_2b", reduced=True)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                              cfg.vocab_size)
    a, _ = model.forward(params, toks, remat=False)
    b, _ = model.forward(params, toks, remat=False)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_w4a8_serving_path():
    """quantize_params + layers.linear: the dual-mode array end to end.
    Structure: packed/scale twins replace eligible projections; stacked [L]
    weights keep their leading axis; decode stays finite and the weight
    bytes drop ~4x."""
    from repro.models.quantized import quantize_params, quantized_bytes
    cfg = get_config("qwen3_8b", reduced=True).replace(
        d_model=256, n_heads=4, n_kv_heads=2, head_dim=64, d_ff=512)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    qparams = quantize_params(params)

    blocks = qparams["blocks"]["attn"]
    assert "wq__qp" in blocks and "wq__qs" in blocks and "wq" not in blocks
    assert blocks["wq__qp"].dtype == jnp.uint8
    assert blocks["wq__qp"].shape[0] == cfg.n_layers  # [L] axis preserved

    dense_b, quant_b = quantized_bytes(params)
    assert dense_b / quant_b > 3.5

    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                              cfg.vocab_size)
    cache = model.init_cache(2, 16, None)
    logits, cache = model.prefill(qparams, toks, cache)
    logits, _ = model.decode_step(qparams, jnp.ones((2,), jnp.int32), cache)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_w4a8_quantized_model_agrees_after_training():
    """On a briefly-trained model the W4A8 path picks the same greedy tokens
    (the Table-I property at smoke scale)."""
    from repro.models.quantized import quantize_params
    from repro.models.api import lm_loss
    from repro.optim import adamw_init, adamw_update
    from repro.data.pipeline import batch_for_step
    cfg = get_config("llama2_7b", reduced=True).replace(
        compute_dtype="float32")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    opt = adamw_init(params)

    @jax.jit
    def step(params, opt, batch):
        loss, grads = jax.value_and_grad(
            lambda p: lm_loss(model, p, batch["tokens"], batch["labels"],
                              remat=False))(params)
        return (*adamw_update(params, grads, opt, lr=jnp.float32(3e-3))[:2],
                loss)

    for s in range(40):
        params, opt, _ = step(params, opt,
                              batch_for_step(cfg.vocab_size, 32, 8, 0, s))

    qparams = quantize_params(params)
    toks = batch_for_step(cfg.vocab_size, 16, 2, 1, 99)["tokens"]
    outs = {}
    for tag, pp in (("dense", params), ("w4a8", qparams)):
        cache = model.init_cache(2, 32, None)
        logits, cache = model.prefill(pp, toks, cache)
        outs[tag] = np.asarray(jnp.argmax(logits, -1))
    assert np.array_equal(outs["dense"], outs["w4a8"])
