"""Ring-KV equivalence properties (hypothesis-driven, with the seeded
explicit-case fallback when hypothesis is absent).

A ``kv_ring=True`` model must be *indistinguishable* from its full-cache
twin — the twin IS the windowed reference, since SWA masking on a full
cache keeps every in-window position exactly:

  * **unwrapped** (total length <= window <= ring): identical logits and
    greedy tokens — the ring is a plain cache until it wraps;
  * **wrapped** (prompt > window, positions past the ring length): greedy
    tokens still match the full-cache twin token-for-token, because every
    position the window can see survives in the ring by construction
    (ring_len >= window + 1 for decode; >= window + chunk - 1 under
    chunked prefill);
  * an engine-level mid-block **EOS retirement landing exactly on a ring
    wrap boundary** frees the slot cleanly and the backfilled request's
    stream is still exact;
  * the O(window) claim is a *reported number*: ``kv_bytes_per_slot``
    scales as ring_len / max_len vs the full-cache twin.

Reduced h2o-danube: window 32, ring 128 rows, max_len 256 — so prompts in
[33, 120] exceed the window and position budgets past 128 wrap the ring.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                    # pragma: no cover
    from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.models.api import build_model
from repro.serving import ContinuousBatchingEngine, Request, ServingEngine

jax.config.update("jax_platform_name", "cpu")

MAX_LEN = 256
CFG_FULL = get_config("h2o-danube-1.8b", reduced=True)     # window 32
CFG_RING = get_config("h2o-danube-1.8b+ring", reduced=True)
WINDOW = CFG_FULL.window
MODEL_FULL = build_model(CFG_FULL)
MODEL_RING = build_model(CFG_RING)
PARAMS = MODEL_FULL.init_params(jax.random.PRNGKey(0))     # twins share params
RING_LEN = int(MODEL_RING.init_cache(1, MAX_LEN, None)["k"].shape[2])


def _greedy(model, prompt_len: int, steps: int):
    """Uniform prefill + greedy decode; returns (tokens [steps], logits
    [steps+1, V]) for a deterministic prompt of ``prompt_len``."""
    toks = jax.random.randint(jax.random.PRNGKey(prompt_len), (1, prompt_len),
                              0, CFG_FULL.vocab_size, jnp.int32)
    cache = model.init_cache(1, MAX_LEN, None)
    logits, cache = model.prefill(PARAMS, toks, cache)
    out_t, out_l = [], [logits]
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for _ in range(steps):
        out_t.append(int(tok[0]))
        logits, cache = model.decode_step(PARAMS, tok, cache)
        out_l.append(logits)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    return out_t, np.asarray(jnp.stack(out_l))


def test_ring_is_strictly_smaller_than_the_context():
    assert RING_LEN == 128 < MAX_LEN
    assert RING_LEN >= WINDOW + 1


@settings(max_examples=6, deadline=None)
@given(st.integers(min_value=1, max_value=24),
       st.integers(min_value=1, max_value=8))
def test_ring_equals_full_twin_unwrapped(prompt_len, steps):
    """Whenever total length stays within the window the ring holds exactly
    the positions the full cache attends — logits and tokens coincide."""
    steps = max(1, min(steps, WINDOW - prompt_len))
    toks_r, log_r = _greedy(MODEL_RING, prompt_len, steps)
    toks_f, log_f = _greedy(MODEL_FULL, prompt_len, steps)
    np.testing.assert_allclose(log_r, log_f, atol=1e-5)
    assert toks_r == toks_f


@settings(max_examples=6, deadline=None)
@given(st.integers(min_value=WINDOW + 1, max_value=200),
       st.integers(min_value=1, max_value=16))
def test_ring_equals_windowed_reference_wrapped(prompt_len, steps):
    """Prompt > window: the ring drops out-of-window history by overwrite,
    the full twin by masking — same attended set, same greedy stream. The
    range runs up to prompts of 200 > ring_len 128, so the high boundary
    cases wrap the ring during *prefill* as well as during decode."""
    toks_r, log_r = _greedy(MODEL_RING, prompt_len, steps)
    toks_f, log_f = _greedy(MODEL_FULL, prompt_len, steps)
    np.testing.assert_allclose(log_r, log_f, atol=1e-4)
    assert toks_r == toks_f


def test_mid_block_eos_on_wrap_boundary_backfills_exactly():
    """A request whose EOS lands on the decode tick that writes ring slot 0
    (the wrap boundary) retires mid-block (decode_ticks=8), and the request
    backfilled into the freed, already-wrapped slot still reproduces its
    per-request stream exactly."""
    prompt_a = np.arange(RING_LEN - 3, dtype=np.int32) % CFG_RING.vocab_size
    p = len(prompt_a)                               # 125
    probe = ContinuousBatchingEngine(MODEL_RING, PARAMS, n_slots=1,
                                     max_len=MAX_LEN, chunk=8)
    free = probe.run([Request(prompt=prompt_a, max_new_tokens=12,
                              rid="probe")])
    toks = free["requests"][0]["tokens"]
    # emitted token j is produced by the decode write at position p + j - 1;
    # j = RING_LEN + 1 - p makes that write land on slot 0 — the boundary
    j = RING_LEN + 1 - p
    eos = toks[j]
    assert eos not in toks[:j], "pick a different seed: accidental early EOS"

    prompt_b = (np.arange(60, dtype=np.int32) * 3 + 1) % CFG_RING.vocab_size
    ref = ServingEngine(MODEL_RING, PARAMS, max_len=MAX_LEN, batch=1)
    want_b = np.asarray(ref.generate(jnp.asarray(prompt_b)[None],
                                     steps=4))[0].tolist()
    assert eos not in want_b, "pick a different prompt_b: contains the EOS"

    eng = ContinuousBatchingEngine(MODEL_RING, PARAMS, n_slots=1,
                                   max_len=MAX_LEN, chunk=8, eos_id=eos,
                                   decode_ticks=8)
    report = eng.run([Request(prompt=prompt_a, max_new_tokens=12, rid="a"),
                      Request(prompt=prompt_b, max_new_tokens=4, rid="b")])
    by_rid = {r["rid"]: r for r in report["requests"]}
    assert by_rid["a"]["tokens"] == toks[:j + 1]    # EOS emitted, then cut
    assert by_rid["a"]["finish_reason"] == "eos"
    assert by_rid["b"]["tokens"] == want_b          # exact in a reused slot
    assert eng.pool.n_free == 1


def test_ring_kv_bytes_per_slot_scale_with_ring():
    """The report's memory line carries the O(window) win: per-slot KV
    bytes shrink by exactly ring_len / max_len vs the full-cache twin."""
    def agg(model):
        eng = ContinuousBatchingEngine(model, PARAMS, n_slots=2,
                                       max_len=MAX_LEN, chunk=8)
        return eng.run([Request(prompt=np.arange(40, dtype=np.int32),
                                max_new_tokens=3, rid="r")])["aggregate"]

    ring, full = agg(MODEL_RING), agg(MODEL_FULL)
    assert ring["kv_rows_per_slot"] == RING_LEN
    assert full["kv_rows_per_slot"] == MAX_LEN
    assert (ring["kv_bytes_per_slot"] * MAX_LEN
            == full["kv_bytes_per_slot"] * RING_LEN)


def test_ring_sizes_to_window_plus_chunk():
    """The engine sizes rings as round128(window + chunk) at construction
    (init_cache(chunk=...)), so the chunked-prefill exactness bound
    ring_len >= window + chunk - 1 holds *by construction* instead of
    rejecting large chunks. A chunk that pushes past max_len degenerates
    the ring to the never-wrapping full cache — larger, still exact."""
    # window 32, chunk 8 -> round128(40) = 128: the O(window) ring
    eng = ContinuousBatchingEngine(MODEL_RING, PARAMS, n_slots=1,
                                   max_len=MAX_LEN, chunk=8)
    assert eng.cache["k"].shape[2] == RING_LEN
    # window 32, chunk 128 -> round128(160) = 256 == max_len: full cache,
    # no wrap, no rejection (this used to raise)
    eng = ContinuousBatchingEngine(MODEL_RING, PARAMS, n_slots=1,
                                   max_len=MAX_LEN, chunk=128)
    assert eng.cache["k"].shape[2] == MAX_LEN


def test_ring_window_just_under_128_boundary_accepts_large_chunks():
    """Regression (ROADMAP open item): a window just under a 128 boundary
    used to leave < chunk slack — round128(window + 1) == 128 supports
    chunks only up to 128 - window + 1 — so the engine rejected large
    chunks. Sizing off window + chunk takes the next 128 step instead, and
    the config serves exactly (greedy == per-request on a wrapping
    trace)."""
    cfg = CFG_RING.replace(window=120)
    model = build_model(cfg)
    eng = ContinuousBatchingEngine(model, PARAMS, n_slots=1, max_len=512,
                                   chunk=64)
    # round128(120 + 64) = 256: holds the bound with room, still < max_len
    assert eng.cache["k"].shape[2] == 256
    assert 64 <= 256 - 120 + 1          # the exactness bound, explicitly
    # and it *serves*: prompt > ring wraps chunked prefill; outputs match
    # the full-cache per-request reference token for token
    full = build_model(cfg.replace(kv_ring=False))
    prompt = np.arange(300, dtype=np.int32) % cfg.vocab_size
    ref = ServingEngine(full, PARAMS, max_len=512, batch=1)
    want = np.asarray(ref.generate(jnp.asarray(prompt)[None],
                                   steps=8))[0].tolist()
    rep = eng.run([Request(prompt=prompt, max_new_tokens=8, rid="r")])
    assert rep["requests"][0]["tokens"] == want
