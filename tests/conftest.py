"""Shared fixtures. NOTE: no XLA_FLAGS here — tests must see the 1-device
world; only launch/dryrun.py forces 512 host devices (in its own process)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def random_qkv(rng, *, b=2, hq=4, hkv=2, s=128, d=32, dtype=jnp.float32):
    q = jnp.asarray(rng.standard_normal((b, hq, d)), dtype)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, d)), dtype)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, d)), dtype)
    lengths = jnp.asarray(rng.integers(1, s + 1, (b,)), jnp.int32)
    return q, k, v, lengths
