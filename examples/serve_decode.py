"""Serving example: batched prefill + per-token SwiftKV decode (the paper's
workload), comparing the decode-attention impls and the incremental-RoPE
(Eq. 11) decode state against direct recomputation.

Run:  PYTHONPATH=src python examples/serve_decode.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.api import build_model
from repro.serving import ServingEngine


def main():
    cfg = get_config("gemma-2b", reduced=True)
    batch, prompt_len, gen = 4, 16, 32
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (batch, prompt_len), 0, cfg.vocab_size)

    outs = {}
    for impl in ("blockwise", "tokenwise", "kernel", "naive"):
        model = build_model(cfg.replace(decode_impl=impl))
        params = model.init_params(jax.random.PRNGKey(0))
        eng = ServingEngine(model, params, max_len=64, batch=batch)
        _ = eng.generate(prompts, steps=2)        # compile
        t0 = time.perf_counter()
        outs[impl] = np.asarray(eng.generate(prompts, steps=gen))
        dt = time.perf_counter() - t0
        print(f"decode_impl={impl:10s} {batch * gen / dt:8.1f} tok/s")

    for impl in ("tokenwise", "kernel", "naive"):
        same = np.array_equal(outs["blockwise"], outs[impl])
        print(f"greedy tokens blockwise == {impl}: {same}")
        assert same, (impl, outs["blockwise"][:, :8], outs[impl][:, :8])

    # incremental vs direct RoPE decode state
    for mode in ("incremental", "direct"):
        model = build_model(cfg.replace(rope_mode=mode))
        params = model.init_params(jax.random.PRNGKey(0))
        eng = ServingEngine(model, params, max_len=64, batch=batch)
        outs[mode] = np.asarray(eng.generate(prompts, steps=gen))
    print("greedy tokens incremental-RoPE == direct-RoPE:",
          np.array_equal(outs["incremental"], outs["direct"]))


if __name__ == "__main__":
    main()
