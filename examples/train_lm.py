"""End-to-end training driver: train a ~100M-param qwen3-family model for a
few hundred steps on the synthetic pipeline, with checkpointing and a
mid-run simulated failure + resume (the fault-tolerance path, exercised).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300] [--d-model 256]
(~100M params needs --d-model 512 --layers 12; the default is laptop-sized.)
"""
import argparse
import tempfile

import jax

from repro.configs import get_config
from repro.models.api import build_model
from repro.distributed.roofline import count_params
from repro.train import TrainLoop, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--vocab", type=int, default=8192)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--inject-failure", action="store_true", default=True)
    args = ap.parse_args()

    cfg = get_config("qwen3-8b").replace(
        d_model=args.d_model, n_layers=args.layers,
        n_heads=max(4, args.d_model // 64), n_kv_heads=max(2, args.d_model // 128),
        head_dim=64, d_ff=args.d_model * 3, vocab_size=args.vocab,
        compute_dtype="float32")
    model = build_model(cfg)
    total, _ = count_params(cfg)
    print(f"model: {cfg.name}-family reduced, {total / 1e6:.1f}M params")

    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_train_")
    step_fn = make_train_step(model, base_lr=1e-3, warmup=20,
                              total_steps=args.steps)

    # one injected transient failure at step 40% through -> the loop restores
    # from the last checkpoint and continues (deterministic data stream)
    boom = {"armed": args.inject_failure}
    fail_at = int(args.steps * 0.4)

    def injector(step):
        if boom["armed"] and step == fail_at:
            boom["armed"] = False
            raise RuntimeError(f"injected node failure at step {step}")

    loop = TrainLoop(model, cfg, step_fn, seq_len=args.seq_len,
                     global_batch=args.batch, ckpt_dir=ckpt_dir,
                     ckpt_every=25, failure_injector=injector)
    history = loop.run(args.steps)

    first, last = history[0]["loss"], history[-1]["loss"]
    print(f"steps={len(history)} loss {first:.3f} -> {last:.3f} "
          f"(ckpt_dir={ckpt_dir})")
    assert last < first, "loss should decrease"
    print("training (with failure/resume) completed")


if __name__ == "__main__":
    main()
