"""Quickstart: the SwiftKV attention algorithm in 60 seconds.

Shows the paper's core contribution end to end:
  1. the per-token single-pass recurrence (Eqs. 5-8) == two-pass softmax
  2. the blockwise TPU form and the Pallas kernel (interpret mode on CPU)
  3. the monoid merge that makes it sequence-parallel
  4. the LUT exponential (Eqs. 9-10) and the Q15.17 fixed-point datapath

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import exp2_lut, fixedpoint, swiftkv
from repro.core.swiftkv import (state_finalize, state_init, state_merge,
                                state_update_block)
from repro.kernels.swiftkv_decode import ops as kernel_ops


def main():
    rng = np.random.default_rng(0)
    d, n = 128, 512
    q = jnp.asarray(rng.standard_normal(d), jnp.float32)
    k = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((n, d)), jnp.float32)

    # 1. paper-faithful per-token single pass vs the two-pass oracle
    out_swift = swiftkv.swiftkv_decode_tokenwise(q, k, v)
    out_ref = swiftkv.softmax_attention_reference(q, k, v)
    print("tokenwise vs two-pass softmax:",
          float(jnp.max(jnp.abs(out_swift - out_ref))))

    # 2. blockwise (TPU-granularity) + the Pallas kernel
    out_blk = swiftkv.swiftkv_decode_blockwise(q, k, v, block_size=128)
    print("blockwise  vs two-pass softmax:",
          float(jnp.max(jnp.abs(out_blk - out_ref))))
    out_kern = kernel_ops.swiftkv_decode(
        q[None, None, :], k[:, None, :][None], v[:, None, :][None],
        jnp.asarray([n], jnp.int32), block_k=128, interpret=True)[0, 0]
    print("Pallas kernel vs two-pass softmax:",
          float(jnp.max(jnp.abs(out_kern - out_ref))))

    # 3. sequence-parallel: fold two halves independently, merge the
    #    (mu, Z, Y) triples — exact, O(d) communication per head
    scale = 1.0 / np.sqrt(d)
    halves = []
    for lo, hi in ((0, n // 2), (n // 2, n)):
        s = (k[lo:hi] @ q) * scale
        st = state_update_block(state_init(d), s, v[lo:hi],
                                jnp.ones(hi - lo))
        halves.append(st)
    merged = state_finalize(state_merge(*halves))
    print("split-fold + monoid merge vs oracle:",
          float(jnp.max(jnp.abs(merged - out_ref))))

    # 4. the hardware numerics (Eqs. 9-10 + Q15.17)
    print("LUT exp max rel err (paper: 5.86e-5):",
          f"{exp2_lut.max_relative_error():.3e}")
    out_fxp = fixedpoint.swiftkv_attention_fxp(
        np.asarray(q), np.asarray(k), np.asarray(v))
    print("Q15.17 fixed-point attention mean abs err:",
          f"{np.mean(np.abs(out_fxp - np.asarray(out_ref))):.2e}")


if __name__ == "__main__":
    main()
