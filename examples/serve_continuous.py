"""Continuous-batching serving example (CPU-runnable).

A ragged Poisson trace flows through the slot pool -> scheduler -> chunked
prefill -> ragged decode pipeline: requests of mixed prompt/output lengths
share a fixed pool of KV slots, retire mid-flight, and freed slots backfill
from the admission queue — while the jit'd decode step keeps one static
batch shape throughout. ``decode_ticks=4`` fuses 4 decode ticks into each
dispatch (on-device EOS/budget retirement keeps outputs exact), so the
host syncs once per 4 tokens — watch ``dispatches_per_token`` in the
summary line.

Run:  PYTHONPATH=src python examples/serve_continuous.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.api import build_model
from repro.serving import (ContinuousBatchingEngine, ServingEngine,
                           poisson_trace)


def main():
    cfg = get_config("llama2-7b", reduced=True)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))

    trace = poisson_trace(n_requests=8, vocab_size=cfg.vocab_size,
                          prompt_len=(4, 24), max_new=(3, 16), seed=7)
    eng = ContinuousBatchingEngine(model, params, n_slots=3, max_len=64,
                                   chunk=8, decode_ticks=4)
    eng.warmup()
    report = eng.run(trace)

    agg = report["aggregate"]
    print(f"{agg['n_retired']} requests, {agg['generated_tokens']} tokens, "
          f"{agg['tokens_per_s']} tok/s, occupancy {agg['mean_occupancy']}, "
          f"ttft p50 {agg['ttft_p50_s']}s, "
          f"{agg['dispatches_per_token']} dispatches/token "
          f"({agg['host_syncs']} host syncs)")
    for r in sorted(report["requests"], key=lambda r: r["rid"]):
        print(f"  req {r['rid']}: prompt {r['prompt_len']:3d} -> "
              f"{r['n_tokens']:3d} tokens ({r['finish_reason']}) "
              f"{r['tokens'][:6]}{'...' if r['n_tokens'] > 6 else ''}")

    # spot-check: continuous output == single-request lock-step (greedy)
    ref_eng = ServingEngine(model, params, max_len=64, batch=1)
    req = trace[0]
    ref = np.asarray(ref_eng.generate(
        jnp.asarray(req.prompt)[None], steps=req.max_new_tokens))[0]
    got = next(r["tokens"] for r in report["requests"]
               if r["rid"] == req.rid)
    same = got == ref.tolist()
    print("continuous == per-request greedy (req 0):", same)
    assert same


if __name__ == "__main__":
    main()
