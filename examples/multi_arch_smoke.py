"""Run every assigned architecture (reduced config) through one forward, one
train step, and a short greedy generation — the 10-arch support matrix as a
runnable script.

Run:  PYTHONPATH=src python examples/multi_arch_smoke.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models.api import build_model, lm_loss, needs_source
from repro.optim import adamw_init, adamw_update
from repro.serving import ServingEngine


def main():
    for arch in ASSIGNED_ARCHS:
        t0 = time.perf_counter()
        cfg = get_config(arch, reduced=True)
        model = build_model(cfg)
        params = model.init_params(jax.random.PRNGKey(0))

        B, S = 2, 16
        toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0,
                                  cfg.vocab_size)
        src = None
        if needs_source(cfg):
            src = jax.random.normal(
                jax.random.PRNGKey(2), (B, cfg.source_len, cfg.d_model),
                jnp.dtype(cfg.compute_dtype)) * 0.02

        # one training step
        loss, grads = jax.value_and_grad(
            lambda p: lm_loss(model, p, toks[:, :-1], toks[:, 1:], src,
                              remat=False))(params)
        opt = adamw_init(params)
        params, opt, _ = adamw_update(params, grads, opt, lr=jnp.float32(1e-3))

        # short generation
        eng = ServingEngine(model, params, max_len=32, batch=B,
                            source_len=cfg.source_len if src is not None
                            else None)
        out = eng.generate(toks[:, :8], steps=4, source=src)

        print(f"{arch:24s} loss={float(loss):7.3f} gen={out.shape} "
              f"({time.perf_counter() - t0:.1f}s)")


if __name__ == "__main__":
    main()
