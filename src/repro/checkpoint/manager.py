"""Fault-tolerant checkpointing: atomic step snapshots (tmp + rename), CRC'd
metadata, keep-last-k, resume-from-latest-valid.

Designed for the restart path at scale: a failed/preempted worker relaunches,
calls ``latest_step()`` / ``restore()``, and the counted data pipeline makes
the resumed run deterministic. Saves run off the step path (device->host copy
first, then async-able file write)."""
from __future__ import annotations

import json
import os
import shutil
import zlib
from pathlib import Path

import jax
import numpy as np


def _keys(tree) -> list[str]:
    return ["/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
            for path, _ in jax.tree_util.tree_flatten_with_path(tree)[0]]


def _flatten(tree) -> dict[str, np.ndarray]:
    leaves = jax.tree_util.tree_leaves(tree)
    return {k: np.asarray(v) for k, v in zip(_keys(tree), leaves)}


class CheckpointManager:
    def __init__(self, directory: str | os.PathLike, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep

    def _step_dir(self, step: int) -> Path:
        return self.dir / f"step_{step:010d}"

    def save(self, step: int, tree, extra: dict | None = None) -> Path:
        """Atomic: write to tmp dir, fsync metadata, rename into place."""
        flat = _flatten(tree)
        tmp = self.dir / f".tmp_step_{step:010d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        np.savez(tmp / "arrays.npz", **flat)
        crc = zlib.crc32((tmp / "arrays.npz").read_bytes())
        meta = {"step": step, "crc32": crc, "n_arrays": len(flat),
                "extra": extra or {}}
        with open(tmp / "meta.json", "w") as f:
            json.dump(meta, f)
            f.flush()
            os.fsync(f.fileno())
        final = self._step_dir(step)
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()
        return final

    def _valid(self, d: Path) -> bool:
        try:
            meta = json.loads((d / "meta.json").read_text())
            return meta["crc32"] == zlib.crc32((d / "arrays.npz").read_bytes())
        except Exception:
            return False

    def steps(self) -> list[int]:
        out = []
        for d in sorted(self.dir.glob("step_*")):
            if self._valid(d):
                out.append(int(d.name.split("_")[1]))
        return out

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, tree_like, step: int | None = None):
        """Restore into the structure of ``tree_like`` (ShapeDtypeStructs or
        arrays). Returns (tree, step, extra)."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no valid checkpoint under {self.dir}")
        d = self._step_dir(step)
        if not self._valid(d):
            raise IOError(f"checkpoint {d} failed CRC validation")
        data = np.load(d / "arrays.npz")
        meta = json.loads((d / "meta.json").read_text())
        treedef = jax.tree_util.tree_structure(tree_like)
        flat_keys = _keys(tree_like)   # structure only; leaves never touched
        leaves = [jax.numpy.asarray(data[k]) for k in flat_keys]
        return jax.tree_util.tree_unflatten(treedef, leaves), step, meta["extra"]

    def _gc(self):
        steps = self.steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
