"""Process-wide distribution context.

Model code (MoE EP dispatch, sequence-parallel decode attention) needs the
concrete mesh to build ``shard_map`` regions, but models are mesh-agnostic by
design. Launchers (dryrun / train / serve) install the mesh + axis roles
here; model modules consult it and fall back to single-device math when it's
unset (tests, examples on one CPU).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax


@dataclass
class DistContext:
    mesh: jax.sharding.Mesh | None = None
    batch_axes: tuple[str, ...] = ()     # token/batch sharding axes (DP/FSDP)
    model_axis: str | None = None        # TP/EP axis

    @property
    def active(self) -> bool:
        return self.mesh is not None

    def axis_size(self, names) -> int:
        if self.mesh is None:
            return 1
        if isinstance(names, str):
            names = (names,)
        n = 1
        for a in names:
            n *= self.mesh.shape[a]
        return n


_CTX = DistContext()


def set_context(mesh, batch_axes=("data",), model_axis="model") -> DistContext:
    global _CTX
    _CTX = DistContext(mesh=mesh, batch_axes=tuple(batch_axes),
                       model_axis=model_axis)
    return _CTX


def clear_context():
    global _CTX
    _CTX = DistContext()


def get_context() -> DistContext:
    return _CTX
