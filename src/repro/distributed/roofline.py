"""Roofline analysis from compiled dry-run artifacts (deliverable g).

This container is CPU-only; TPU v5e is the *target*. We therefore derive the
three roofline terms per (arch, shape, mesh) cell from the compiled HLO rather
than wall-clock:

    compute term    = HLO_FLOPs        / (chips x PEAK_FLOPS)
    memory term     = HLO_bytes        / (chips x HBM_BW)
    collective term = collective_bytes / (chips x ICI_BW)

``compiled.cost_analysis()`` supplies HLO_FLOPs / HLO_bytes (whole-program,
all chips). Collective bytes are NOT in cost_analysis: we parse the optimized
HLO module text and sum operand sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute, weighting each kind by the
per-chip traffic its ring implementation moves over ICI links.

Hardware constants (TPU v5e, per chip):
    197 TFLOP/s bf16 peak, 819 GB/s HBM, ~50 GB/s/link ICI (prompt-specified).
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field

# ---------------------------------------------------------------------------
# TPU v5e hardware constants
# ---------------------------------------------------------------------------
PEAK_FLOPS = 197e12     # bf16 FLOP/s per chip
HBM_BW = 819e9          # bytes/s per chip
ICI_BW = 50e9           # bytes/s per ICI link (prompt-specified)

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "s4": 1, "s8": 1, "u2": 1, "u4": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

_COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                     "all-to-all", "collective-permute")

# one HLO shape token, e.g. ``bf16[8,128,4096]{2,1,0}`` or ``f32[]``
_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]+(?:e[0-9]+m[0-9]+(?:fn)?)?|pred|token)"
                       r"\[([0-9,]*)\]")


def shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_OPND_RE = re.compile(r"%([\w.\-]+)")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _build_symbol_table(hlo_text: str) -> dict[str, int]:
    """Map instruction name -> result bytes, for operand-size lookups.
    (This XLA version prints operands as bare %names, not typed.)"""
    table: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.groups()
        paren = rhs.find("(")
        head = rhs[:paren] if paren > 0 else rhs
        table[name] = sum(shape_bytes(dt, dims)
                          for dt, dims in _SHAPE_RE.findall(head))
    return table


def _group_size(line: str) -> int | None:
    """Collective group size from replica_groups (iota or explicit list)."""
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))          # [n_groups, group_size]<=[...]
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return None


def _operand_names(line: str) -> list[str]:
    """Operand %names inside the op-call parens (attributes excluded)."""
    paren = line.find("(")
    if paren < 0:
        return []
    args = line[paren + 1:]
    depth = 1
    for i, ch in enumerate(args):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                args = args[:i]
                break
    return _OPND_RE.findall(args)


@dataclass
class CollectiveStats:
    """Per-kind operand bytes + per-chip ICI traffic (ring model).

    Post-GSPMD HLO is the *per-device* program, so every parsed shape is a
    per-chip size already. Ring-algorithm traffic per chip:

        all-reduce    : 2 x (n-1)/n x operand bytes (RS + AG phases)
        all-gather    : (n-1)/n x output bytes
        reduce-scatter: (n-1)/n x operand bytes
        all-to-all    : (n-1)/n x operand bytes
        collective-permute : operand bytes (single hop)
    """
    op_bytes: dict[str, int] = field(default_factory=dict)
    op_counts: dict[str, int] = field(default_factory=dict)
    ici_bytes: float = 0.0

    @property
    def total_operand_bytes(self) -> int:
        return sum(self.op_bytes.values())


def parse_collectives(hlo_text: str, default_group: int = 2) -> CollectiveStats:
    """Scan optimized per-device HLO; accumulate collective operand sizes and
    ring-model ICI traffic. ``-start`` variants count once (their ``-done``
    twin carries no new traffic)."""
    table = _build_symbol_table(hlo_text)
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if "-done(" in stripped or "-done.." in stripped:
            continue
        for kind in _COLLECTIVE_KINDS:
            if f" {kind}(" in stripped or f" {kind}-start(" in stripped:
                opnds = _operand_names(stripped)
                ob = sum(table.get(o, 0) for o in opnds)
                m = _DEF_RE.match(stripped)
                rb = table.get(m.group(1), 0) if m else 0
                n = _group_size(stripped) or default_group
                f = (n - 1) / max(n, 1)
                if kind == "all-reduce":
                    stats.ici_bytes += 2 * f * ob
                elif kind == "all-gather":
                    stats.ici_bytes += f * rb
                elif kind == "collective-permute":
                    stats.ici_bytes += ob
                else:  # reduce-scatter, all-to-all
                    stats.ici_bytes += f * ob
                stats.op_bytes[kind] = stats.op_bytes.get(kind, 0) + ob
                stats.op_counts[kind] = stats.op_counts.get(kind, 0) + 1
                break
    return stats


# ---------------------------------------------------------------------------
# Roofline terms
# ---------------------------------------------------------------------------

@dataclass
class RooflineReport:
    """Post-GSPMD ``cost_analysis()`` is *per-device*, so ``hlo_flops`` /
    ``hlo_bytes`` here are per-chip; global figures are chips x per-chip.
    The three terms are then exactly the prompt's formulas:
    global_FLOPs / (chips x peak) == per-chip FLOPs / peak."""
    arch: str
    shape: str
    mesh: str
    n_chips: int
    hlo_flops: float              # per-chip FLOPs
    hlo_bytes: float              # per-chip bytes accessed
    collective_op_bytes: int      # summed operand sizes (per-chip program)
    collective_ici_bytes: float   # per-chip ICI traffic (ring model)
    bytes_per_chip: float         # peak live memory per device
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0
    model_flops: float = 0.0      # 6·N·D useful flops (global)
    op_counts: dict = field(default_factory=dict)

    def finalize(self):
        self.t_compute = self.hlo_flops / PEAK_FLOPS
        self.t_memory = self.hlo_bytes / HBM_BW
        # collective term: per-chip ICI traffic over per-chip link bandwidth
        self.t_collective = self.collective_ici_bytes / ICI_BW
        return self

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_fraction(self) -> float:
        global_flops = self.hlo_flops * self.n_chips
        return self.model_flops / global_flops if global_flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the dominant-resource roofline the *useful* work
        achieves: (model_flops-at-peak time) / (bound time). For memory- or
        collective-bound cells this reads as how much of the step time is the
        unavoidable compute."""
        if self.t_bound <= 0:
            return 0.0
        t_useful = self.model_flops / (self.n_chips * PEAK_FLOPS)
        return t_useful / self.t_bound

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.n_chips,
            "chip_gflops": self.hlo_flops / 1e9,
            "chip_gbytes": self.hlo_bytes / 1e9,
            "coll_gbytes": self.collective_op_bytes / 1e9,
            "ici_gbytes": self.collective_ici_bytes / 1e9,
            "bytes_per_chip_gb": self.bytes_per_chip / 1e9,
            "t_compute_ms": self.t_compute * 1e3,
            "t_memory_ms": self.t_memory * 1e3,
            "t_collective_ms": self.t_collective * 1e3,
            "dominant": self.dominant,
            "model_gflops": self.model_flops / 1e9,
            "useful_frac": self.useful_flops_fraction,
            "roofline_frac": self.roofline_fraction,
            "op_counts": self.op_counts,
        }


def analyze(arch: str, shape: str, mesh_name: str, n_chips: int,
            cost_analysis: dict, hlo_text: str,
            bytes_per_chip: float, model_flops: float,
            tp_size: int) -> RooflineReport:
    stats = parse_collectives(hlo_text, default_group=tp_size)
    rep = RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, n_chips=n_chips,
        hlo_flops=float(cost_analysis.get("flops", 0.0)),
        hlo_bytes=float(cost_analysis.get("bytes accessed", 0.0)),
        collective_op_bytes=stats.total_operand_bytes,
        collective_ici_bytes=stats.ici_bytes,
        bytes_per_chip=bytes_per_chip,
        model_flops=model_flops,
        op_counts=dict(stats.op_counts),
    )
    return rep.finalize()


# ---------------------------------------------------------------------------
# Model-FLOPs accounting (6·N·D rule, MoE-active-aware)
# ---------------------------------------------------------------------------

def count_params(cfg) -> tuple[int, int]:
    """(total, active) parameter counts from a ModelConfig — analytic, no
    instantiation. Active differs from total only for MoE (top_k experts)."""
    d, dff, v = cfg.d_model, cfg.d_ff, cfg.vocab_size
    dh = cfg.resolved_head_dim
    attn = d * dh * (cfg.n_heads + 2 * cfg.n_kv_heads) + cfg.n_heads * dh * d

    def ffn(n_used):
        per = d * dff * (3 if cfg.gated_mlp else 2)
        return per * max(n_used, 1) + (d * cfg.n_experts if cfg.n_experts else 0)

    if cfg.family == "ssm":
        d_att = 5 * d * d + d * max(32, d // 16) * 2     # rwkv time-mix
        d_ffn = 2 * d * dff + d * d
        layer_total = layer_active = d_att + d_ffn
        attn = 0
    else:
        layer_total = attn + ffn(cfg.n_experts or 1)
        layer_active = attn + ffn(cfg.top_k if cfg.n_experts else 1)
        if cfg.family == "hybrid":
            d_inner = cfg.ssm_expand * d
            mamba = (d * 2 * d_inner + d_inner * (1 + 2 * cfg.ssm_state)
                     + d_inner * d + cfg.ssm_conv * d_inner)
            layer_total += mamba
            layer_active += mamba

    n_layers = cfg.n_layers + getattr(cfg, "encoder_layers", 0)
    total = n_layers * layer_total + v * d * (1 if cfg.tie_embeddings else 2)
    active = n_layers * layer_active + v * d * (1 if cfg.tie_embeddings else 2)
    return int(total), int(active)


def model_flops_for_cell(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N_active·D for training; 2·N_active·D for inference
    (forward only). D = tokens processed by the step."""
    _, active = count_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * active * tokens
    # decode: one token per row; attention reads the KV cache (not in 2ND —
    # add the 2·cache-dot FLOPs explicitly)
    tokens = shape.global_batch
    base = 2.0 * active * tokens
    if cfg.family != "ssm":
        dh = cfg.resolved_head_dim
        kv_len = min(shape.seq_len, cfg.window) if cfg.window else shape.seq_len
        attn_flops = (4.0 * cfg.n_heads * dh * kv_len) * cfg.n_layers * tokens
        base += attn_flops
    return base
