"""Sharding rules: parameter/batch/cache PartitionSpecs per (arch, shape).

Scheme (DESIGN.md §3):
  * ``pod``   — pure DP across pods (gradient all-reduce over DCI).
  * ``data``  — batch DP + FSDP for training (params/optimizer sharded, gathered
                at use); TP-only (no FSDP) for serving unless the model doesn't
                fit, so decode steps don't pay per-layer param all-gathers.
  * ``model`` — TP: d_ff & attention-projection output dims, vocab, MoE experts
                (EP). Decode KV caches sequence-shard over ``model`` and batch-
                shard over (pod, data); the SwiftKV monoid merge makes the
                sequence split exact (sp_attention.py).

GSPMD handles non-divisible dims by padding (e.g. 25 heads over 16), so rules
only avoid *egregiously* uneven splits.
"""
from __future__ import annotations

import re
from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig, ShapeSpec


@dataclass(frozen=True)
class MeshRules:
    mesh: Mesh

    @property
    def has_pod(self) -> bool:
        return "pod" in self.mesh.axis_names

    @property
    def batch_axes(self):
        return ("pod", "data") if self.has_pod else ("data",)

    @property
    def dp_size(self) -> int:
        n = 1
        for a in self.batch_axes:
            n *= self.mesh.shape[a]
        return n

    @property
    def tp_size(self) -> int:
        return self.mesh.shape["model"]


def mesh_axis_names(multi_pod: bool):
    return ("pod", "data", "model") if multi_pod else ("data", "model")


# (path regex, spec for trailing dims) — first match wins. ``F`` marks the
# FSDP axis (data for train, None for serve); leading [L]/[G] scan axes are
# auto-prepended as None.
_RULES: list[tuple[str, tuple]] = [
    (r"embed$",                    ("model", None)),
    # unembed: model-parallel over vocab ONLY — FSDP-sharding its d dim makes
    # the contraction partial over 'data' and GSPMD all-reduces full [B,S,V]
    # f32 logits (33.6 GB/chip on the 90B vlm). 0.26 GB/chip replicated cost.
    (r"unembed$",                  (None, "model")),
    (r"router$",                   ("F", None)),
    # column-parallel (output dim sharded); __qp/__qs are the W4A8
    # packed-weight / group-scale twins (same layout, N-dim sharded)
    (r"(wq|wk|wv|up|gate)(__q[ps])?$", ("F", "model")),
    (r"(in_proj|x_proj)$",         ("F", "model")),
    (r"(wr|wg|fk|fr|w_a)$",        ("F", "model")),
    # row-parallel (input dim sharded); W4A8 packed/scale twins keep the
    # K (reduction) dim on the model axis like their dense originals
    (r"(wo|down)__q[ps]$",         ("model", None)),
    (r"(wo|down|out_proj|fv|w_b)$", ("model", "F")),
    (r"conv_w$",                   (None, "model")),
    (r"a_log$",                    ("model", None)),
]


def _spec_for(path: str, ndim: int, fsdp) -> P:
    if ndim <= 1:
        return P()  # scalars / per-layer scalars & vectors: replicated
    # MoE expert stacks [L, E, din, dout]: experts over model (EP), FSDP on din
    if re.search(r"ffn/(up|gate|down)$", path) and ndim == 4:
        return P(None, "model", fsdp, None)
    for pat, trailing in _RULES:
        if re.search(pat, path):
            tr = tuple(fsdp if a == "F" else a for a in trailing)
            if len(tr) > ndim:
                tr = tr[-ndim:]
            lead = (None,) * (ndim - len(tr))
            return P(*lead, *tr)
    return P()  # norms, scalars, small vectors: replicated


def _tree_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
             for path, _ in flat]
    return paths, [l for _, l in flat], treedef


def _axis_size(mesh: Mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, (tuple, list)):
        n = 1
        for a in name:
            n *= mesh.shape[a]
        return n
    return mesh.shape[name]


def fixup_divisibility(spec: P, shape, mesh: Mesh) -> P:
    """Drop sharding on dims the mesh axes don't divide evenly (503-vocab
    reduced configs, 25-head hymba, 51865-vocab whisper, batch=1 decode).
    jit in_shardings require exact divisibility; GSPMD pads only internal
    values, not arguments."""
    dims = tuple(shape)
    out = []
    for i, name in enumerate(tuple(spec) + (None,) * (len(dims) - len(spec))):
        if name is not None and dims[i] % _axis_size(mesh, name) != 0:
            name = None
        out.append(name)
    return P(*out)


def fixup_tree(specs_tree, shapes_tree, mesh: Mesh):
    """Apply ``fixup_divisibility`` leaf-wise over matching pytrees."""
    return jax.tree.map(
        lambda s, l: fixup_divisibility(s, getattr(l, "shape", ()), mesh),
        specs_tree, shapes_tree,
        is_leaf=lambda x: isinstance(x, P))


def param_specs(params_shapes, rules: MeshRules, *, train: bool):
    """Map a params shape-pytree to PartitionSpecs. ``train``: FSDP over data;
    serve: TP-only (fsdp=None). Non-divisible dims fall back to replicated."""
    fsdp = "data" if train else None
    paths, leaves, treedef = _tree_with_paths(params_shapes)
    specs = [fixup_divisibility(
                 _spec_for(p, getattr(l, "ndim", 0), fsdp),
                 getattr(l, "shape", ()), rules.mesh)
             for p, l in zip(paths, leaves)]
    return jax.tree_util.tree_unflatten(treedef, specs)


def batch_specs(cfg: ModelConfig, shape: ShapeSpec, rules: MeshRules):
    """Specs for the input batch of one cell."""
    bd = rules.batch_axes if shape.global_batch % rules.dp_size == 0 else None
    if shape.kind in ("train", "prefill"):
        specs = {"tokens": P(bd, None)}
        if shape.kind == "train":
            specs["labels"] = P(bd, None)
        if cfg.family in ("vlm", "audio"):
            specs["source"] = P(bd, None, None)
        return specs
    # decode: tokens [B] + cache pytree
    return {"tokens": P(bd), "cache": cache_specs(cfg, shape, rules)}


def cache_specs(cfg: ModelConfig, shape: ShapeSpec, rules: MeshRules):
    """KV caches: batch over (pod,data) when divisible; *sequence* over the
    model axis (SwiftKV sequence-parallel decode). Recurrent states: batch
    over data axes, channels over model."""
    bd = rules.batch_axes if shape.global_batch % rules.dp_size == 0 else None
    # ring KV caches are ~window-sized: replicate the (tiny) seq dim instead
    # of paying seq-shard collectives
    seq_ax = None if cfg.kv_ring else "model"
    specs = {"len": P(bd)}
    if cfg.family == "ssm":
        specs.update(rwkv_att=P(None, bd, "model"),
                     rwkv_ffn=P(None, bd, "model"),
                     rwkv_wkv=P(None, bd, "model", None, None))
        return specs
    specs["k"] = P(None, bd, seq_ax, None, None)
    specs["v"] = specs["k"]
    if cfg.rotary_dim:
        specs["rope_cos"] = P(bd, None)
        specs["rope_sin"] = P(bd, None)
    if cfg.family == "hybrid":
        specs["mamba_conv"] = P(None, bd, None, "model")
        specs["mamba_ssm"] = P(None, bd, "model", None)
    if cfg.cross_attn_every:
        specs["cross_k"] = P(None, bd, None, None, None)
        specs["cross_v"] = specs["cross_k"]
        specs["source_len"] = P(bd)
    return specs


def named(tree_of_specs, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_of_specs,
                        is_leaf=lambda x: isinstance(x, P))
