"""Version-compat shim for ``shard_map`` (mirrors kernels/pallas_compat.py).

``shard_map`` moved across jax releases: 0.4.x ships it as
``jax.experimental.shard_map.shard_map`` with a ``check_rep`` flag; newer
releases promote it to ``jax.shard_map`` and rename the replication check to
``check_vma`` (varying-manual-axes). Callers import the resolved wrapper from
here so the explicit-SPMD paths (expert-parallel MoE dispatch,
sequence-parallel decode attention) lower on whichever jax the image bakes in.

``pcast`` (marking a value as device-varying for the vma analysis) only
exists on the newer API; on releases without it the replication check is the
legacy ``check_rep`` — which our callers disable anyway — so the fallback is
an identity.
"""
from __future__ import annotations

import jax

__all__ = ["shard_map", "pcast"]


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` when available, else the ``jax.experimental`` form
    with ``check_vma`` mapped onto the old ``check_rep`` flag."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)


def pcast(x, axes, *, to: str = "varying"):
    """``jax.lax.pcast`` when available; identity on releases predating the
    vma tracking (their ``check_rep`` analysis needs no cast)."""
    if hasattr(jax.lax, "pcast"):
        return jax.lax.pcast(x, axes, to=to)
    return x
