from .sharding import (batch_specs, cache_specs, param_specs, mesh_axis_names,
                       MeshRules)
from . import roofline

__all__ = ["batch_specs", "cache_specs", "param_specs", "mesh_axis_names",
           "MeshRules", "roofline"]
