"""Sequence-parallel SwiftKV decode attention (beyond-paper, DESIGN.md §2).

The KV cache shards along the *sequence* axis across the data mesh axes; each
device folds its shard with the single-pass blockwise recurrence into a
partial ``(mu, Z, Y)`` triple, and one tiny all-gather + associative
``state_merge`` tree produces the exact global attention output. Per-device
collective traffic is O(G·D) — independent of context length — which is what
makes the 500k-context decode shape run at all (a 300GB+ cache never moves).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import swiftkv
from repro.core.swiftkv import SwiftKVState, state_finalize, state_merge
from repro.distributed.shard_map_compat import pcast, shard_map


def _local_partial_state(q, k_loc, v_loc, length, shard_offset, *,
                         window, block_size, scale, vary_axes=()):
    """One device's fold over its KV shard. q: [G, D]; k/v_loc: [S_loc, D];
    returns SwiftKVState with batch_shape (G,). ``vary_axes``: manual mesh
    axes the state varies over (shard_map vma tracking)."""
    g, d = q.shape
    s_loc = k_loc.shape[0]
    n_blocks = -(-s_loc // block_size)
    pad = n_blocks * block_size - s_loc
    if pad:
        k_loc = jnp.pad(k_loc, ((0, pad), (0, 0)))
        v_loc = jnp.pad(v_loc, ((0, pad), (0, 0)))
    qf = q.astype(jnp.float32)

    def body(i, state):
        start = i * block_size
        k_blk = jax.lax.dynamic_slice_in_dim(k_loc, start, block_size)
        v_blk = jax.lax.dynamic_slice_in_dim(v_loc, start, block_size)
        t_loc = start + jnp.arange(block_size)                  # local pos
        t = shard_offset + t_loc                                # global pos
        valid = (t < length) & (t_loc < s_loc)  # mask block padding too
        if window is not None:
            valid &= t >= length - window
        s_blk = jnp.einsum("gd,kd->gk", qf, k_blk.astype(jnp.float32)) * scale
        return swiftkv.state_update_block(
            state, jnp.where(valid[None, :], s_blk, swiftkv.NEG_INF),
            v_blk.astype(jnp.float32)[None], valid[None, :].astype(jnp.float32))

    init = swiftkv.state_init(d, batch_shape=(g,))
    if vary_axes:  # mark the carry as device-varying for shard_map's vma check
        init = jax.tree.map(
            lambda x: pcast(x, vary_axes, to="varying"), init)
    return jax.lax.fori_loop(0, n_blocks, body, init)


def decode_attention_sp(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                        lengths: jax.Array, *, mesh: jax.sharding.Mesh,
                        seq_axes, batch_axes=None, window: int | None = None,
                        block_size: int = 512,
                        scale: float | None = None) -> jax.Array:
    """q: [B, Hq, D]; caches [B, S, Hkv, D] with S sharded over ``seq_axes``
    and B over ``batch_axes`` (both preserved — no resharding of the cache);
    lengths [B]. Returns [B, Hq, D] sharded over ``batch_axes``."""
    b, hq, d = q.shape
    s_len, hkv = k_cache.shape[1], k_cache.shape[2]
    g = hq // hkv
    scale = (1.0 / d ** 0.5) if scale is None else scale
    seq_axes = tuple(seq_axes) if not isinstance(seq_axes, str) else (seq_axes,)
    if batch_axes is None:
        from repro.distributed.context import get_context
        ctx = get_context()
        batch_axes = ctx.batch_axes if ctx.active else ()
    bd_size = 1
    for a in batch_axes:
        bd_size *= mesh.shape[a]
    bd = tuple(batch_axes) if (bd_size > 1 and b % bd_size == 0) else None
    n_shards = 1
    for a in seq_axes:
        n_shards *= mesh.shape[a]
    s_loc = s_len // n_shards

    def shard_fn(q_s, k_s, v_s, len_s):
        # q_s: [B, Hkv, G, D]; k_s/v_s: [B, S_loc, Hkv, D] (this shard)
        idx = jax.lax.axis_index(seq_axes)
        offset = idx * s_loc

        def one(qh, kh, vh, ln):
            return _local_partial_state(qh, kh, vh, ln, offset, window=window,
                                        block_size=block_size, scale=scale,
                                        vary_axes=seq_axes)

        per_head = jax.vmap(one, in_axes=(0, 0, 0, None))       # Hkv
        per_batch = jax.vmap(per_head, in_axes=(0, 0, 0, 0))    # B
        st = per_batch(q_s, jnp.swapaxes(k_s, 1, 2), jnp.swapaxes(v_s, 1, 2),
                       len_s)                                    # [B,Hkv,G,...]
        # merge partial triples across the sequence shards (tiny collective)
        parts = jax.lax.all_gather(st, seq_axes, axis=0, tiled=False)
        acc = jax.tree.map(lambda x: x[0], parts)
        for i in range(1, n_shards):
            acc = state_merge(acc, jax.tree.map(lambda x: x[i], parts))
        return state_finalize(acc).astype(q_s.dtype)

    qg = q.reshape(b, hkv, g, d)
    spec_kv = P(bd, seq_axes, None, None)
    # check_vma=False: after the all-gather + associative merge every seq
    # shard holds the identical value, which the static vma analysis can't
    # infer. Batch stays sharded end to end — the cache never reshards.
    out = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(P(bd), spec_kv, spec_kv, P(bd)),
        out_specs=P(bd),
        check_vma=False,
    )(qg, k_cache, v_cache, lengths)
    return out.reshape(b, hq, d)
