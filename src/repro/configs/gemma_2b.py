"""Gemma-2B [arXiv:2403.08295]: MQA (kv=1), GeGLU, head_dim=256. The MQA
decode shares ONE KV-cache scan across all 8 query heads (DESIGN.md §4).
Full attention -> long_500k skipped."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b", family="dense", vocab_size=256_000, d_model=2_048,
    n_layers=18, n_heads=8, n_kv_heads=1, d_ff=16_384, head_dim=256,
    act="gelu", gated_mlp=True, tie_embeddings=True,
    notes="MQA; GeGLU; tied embeddings",
)

REDUCED = CONFIG.replace(vocab_size=503, d_model=64, n_layers=2, n_heads=4,
                         n_kv_heads=1, head_dim=16, d_ff=128,
                         compute_dtype="float32")
