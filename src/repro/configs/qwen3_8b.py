"""Qwen3-8B [hf:Qwen/Qwen3-8B]: dense GQA (kv=8) with per-head qk-norm.
Full attention -> long_500k skipped."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-8b", family="dense", vocab_size=151_936, d_model=4_096,
    n_layers=36, n_heads=32, n_kv_heads=8, d_ff=12_288, head_dim=128,
    qk_norm=True, rope_base=1_000_000.0,
    notes="qk_norm; GQA 32/8",
)

REDUCED = CONFIG.replace(vocab_size=503, d_model=64, n_layers=2, n_heads=4,
                         n_kv_heads=2, head_dim=16, d_ff=96,
                         compute_dtype="float32")
