"""Whisper-small backbone [arXiv:2212.04356]: 12L encoder + 12L decoder,
conv/mel frontend STUBBED (precomputed frame embeddings, source_len=1500).
Decoder shapes exercise the self-attn KV cache; encoder has no decode step.
Full attention -> long_500k skipped."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="audio", vocab_size=51_865, d_model=768,
    n_layers=12, n_heads=12, n_kv_heads=12, d_ff=3_072, head_dim=64,
    act="gelu", gated_mlp=False, encoder_layers=12, source_len=1_500,
    cross_attn_every=1,
    notes="enc-dec; plain GELU MLP; cross-attn in every decoder layer",
)

REDUCED = CONFIG.replace(vocab_size=503, d_model=64, n_layers=2, n_heads=4,
                         n_kv_heads=4, head_dim=16, d_ff=96, encoder_layers=2,
                         source_len=24, compute_dtype="float32")
