"""Mistral-Nemo-12B [hf:mistralai/Mistral-Nemo-Base-2407]: dense GQA,
head_dim 128 (q-proj 5120->4096), 128k context. Full attention ->
long_500k skipped."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b", family="dense", vocab_size=131_072,
    d_model=5_120, n_layers=40, n_heads=32, n_kv_heads=8, d_ff=14_336,
    head_dim=128, rope_base=1_000_000.0,
    notes="128k ctx; head_dim 128 != d_model/n_heads",
)

REDUCED = CONFIG.replace(vocab_size=503, d_model=64, n_layers=2, n_heads=4,
                         n_kv_heads=2, head_dim=16, d_ff=96,
                         compute_dtype="float32")
