"""LLaMA2-7B — the paper's primary evaluation model (§V, Tables I/III)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama2-7b", family="dense", vocab_size=32_000, d_model=4_096,
    n_layers=32, n_heads=32, n_kv_heads=32, d_ff=11_008, head_dim=128,
    notes="paper model; 32-head MHA, one head per SKV processor",
)

REDUCED = CONFIG.replace(vocab_size=503, d_model=64, n_layers=2, n_heads=4,
                         n_kv_heads=4, head_dim=16, d_ff=96,
                         compute_dtype="float32")
