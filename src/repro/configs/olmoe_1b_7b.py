"""OLMoE-1B-7B [arXiv:2409.02060]: 64 experts top-8, d_ff(expert)=1024,
kv=16 (full MHA-style KV). Full attention -> long_500k skipped."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b", family="moe", vocab_size=50_304, d_model=2_048,
    n_layers=16, n_heads=16, n_kv_heads=16, d_ff=1_024, head_dim=128,
    n_experts=64, top_k=8, qk_norm=True,
    notes="64e top-8 fine-grained experts; qk-norm per OLMoE",
)

REDUCED = CONFIG.replace(vocab_size=503, d_model=64, n_layers=2, n_heads=4,
                         n_kv_heads=4, head_dim=16, d_ff=32, n_experts=8,
                         top_k=2, capacity_factor=8.0, compute_dtype="float32")
