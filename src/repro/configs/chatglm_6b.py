"""ChatGLM-6B — the paper's second evaluation model (Table III). Partial
rotary (half the head dims)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chatglm-6b", family="dense", vocab_size=130_528, d_model=4_096,
    n_layers=28, n_heads=32, n_kv_heads=32, d_ff=16_384, head_dim=128,
    rotary_frac=0.5, act="gelu", gated_mlp=False,
    notes="paper model; partial rotary; plain GELU FFN",
)

REDUCED = CONFIG.replace(vocab_size=503, d_model=64, n_layers=2, n_heads=4,
                         n_kv_heads=4, head_dim=16, d_ff=96,
                         compute_dtype="float32")
