"""RWKV6-3B "Finch" [arXiv:2404.05892]: attention-free, data-dependent decay.
SwiftKV attention inapplicable (no KV cache / softmax) — DESIGN.md §4.
O(1)-state decode -> long_500k runs."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b", family="ssm", vocab_size=65_536, d_model=2_560,
    n_layers=32, n_heads=40, n_kv_heads=40, d_ff=8_960, rwkv_head_dim=64,
    rotary_frac=0.0, sub_quadratic=True,
    notes="attention-free; wkv state [H,64,64] per layer",
)

REDUCED = CONFIG.replace(vocab_size=503, d_model=64, n_layers=2, n_heads=4,
                         n_kv_heads=4, d_ff=96, rwkv_head_dim=16,
                         compute_dtype="float32")
