"""H2O-Danube-1.8B [arXiv:2401.16818]: llama+mistral mix with sliding-window
attention -> sub-quadratic (long_500k runs with the window)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b", family="dense", vocab_size=32_000, d_model=2_560,
    n_layers=24, n_heads=32, n_kv_heads=8, d_ff=6_912, head_dim=80,
    window=4_096, sub_quadratic=True,
    notes="SWA window 4096",
)

REDUCED = CONFIG.replace(vocab_size=503, d_model=64, n_layers=2, n_heads=4,
                         n_kv_heads=2, head_dim=16, d_ff=96, window=32,
                         compute_dtype="float32")
