"""Hymba-1.5B [arXiv:2411.13676]: hybrid layers with parallel attention and
Mamba heads; SWA on the attention branch -> sub-quadratic (long_500k runs)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid", vocab_size=32_001, d_model=1_600,
    n_layers=32, n_heads=25, n_kv_heads=5, d_ff=5_504, head_dim=64,
    ssm_state=16, ssm_conv=4, ssm_expand=2, window=1_024,
    sub_quadratic=True,
    notes="parallel attn+mamba heads; SWA window 1024 on the attn branch",
)

REDUCED = CONFIG.replace(vocab_size=503, d_model=64, n_layers=2, n_heads=5,
                         n_kv_heads=5, head_dim=16, d_ff=96, window=32,
                         ssm_state=4, compute_dtype="float32")
