"""Llama-3.2-Vision-90B backbone [hf:meta-llama]: decoder with dedicated
gated cross-attention layers every 5th layer; vision frontend is a stub
(precomputed patch embeddings). Pure full attention -> long_500k skipped."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b", family="vlm", vocab_size=128_256,
    d_model=8_192, n_layers=100, n_heads=64, n_kv_heads=8, d_ff=28_672,
    head_dim=128, rope_base=500_000.0, cross_attn_every=5, source_len=1_600,
    notes="100L = 80 self + 20 cross; image embeds stubbed at 1600 tokens",
)

REDUCED = CONFIG.replace(vocab_size=503, d_model=64, n_layers=5, n_heads=4,
                         n_kv_heads=2, head_dim=16, d_ff=96, source_len=24,
                         compute_dtype="float32")
