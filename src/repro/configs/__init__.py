"""Config registry: the 10 assigned architectures + the paper's own two models
(LLaMA2-7B, ChatGLM-6B). ``get_config(name, reduced=True)`` returns the
smoke-test-sized variant of the same family."""
from __future__ import annotations

import importlib

from repro.models.config import SHAPES, ModelConfig, ShapeSpec, shape_applicable

ARCH_IDS = [
    "hymba_1p5b", "llama32_vision_90b", "llama4_scout_17b_16e", "olmoe_1b_7b",
    "qwen3_8b", "h2o_danube_1p8b", "gemma_2b", "mistral_nemo_12b",
    "rwkv6_3b", "whisper_small",
    # paper's evaluation models
    "llama2_7b", "chatglm_6b",
]

ASSIGNED_ARCHS = ARCH_IDS[:10]

_ALIAS = {
    "hymba-1.5b": "hymba_1p5b",
    "llama-3.2-vision-90b": "llama32_vision_90b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_16e",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "qwen3-8b": "qwen3_8b",
    "h2o-danube-1.8b": "h2o_danube_1p8b",
    "gemma-2b": "gemma_2b",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "rwkv6-3b": "rwkv6_3b",
    "whisper-small": "whisper_small",
    "llama2-7b": "llama2_7b",
    "chatglm-6b": "chatglm_6b",
}


def get_config(name: str, reduced: bool = False) -> ModelConfig:
    if name.endswith("+w4a8"):
        # quantized serving variant: int4-packed projections + int8
        # activations (paper §IV-B) AND symmetric int8 KV with per-(slot,
        # position, head) f32 scales — ~4x less weight traffic and ~4x
        # smaller kv_bytes_per_slot. Deliberately NOT token-exact: +w4a8
        # configs are held to the measured-agreement conformance tier
        # (greedy agreement >= 0.90 vs the fp32 twin; docs/serving.md
        # §Quantized serving) instead of token equality. Suffixes compose:
        # "<arch>+ring+w4a8" serves a quantized ring cache.
        base = get_config(name[: -len("+w4a8")], reduced)
        return base.replace(w4a8_serve=True, name=base.name + "+w4a8")
    if name.endswith("+ring"):
        # ring-KV variant of an SWA arch: O(window) per-slot caches
        # (serving_bench --arch h2o-danube-1.8b+ring, conformance tests)
        base = get_config(name[: -len("+ring")], reduced)
        if not base.window:
            raise ValueError(f"{name}: kv_ring needs a sliding-window arch")
        return base.replace(kv_ring=True, name=base.name + "+ring")
    mod_name = _ALIAS.get(name, name.replace("-", "_").replace(".", "p"))
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.REDUCED if reduced else mod.CONFIG


def all_configs(reduced: bool = False) -> dict[str, ModelConfig]:
    return {a: get_config(a, reduced) for a in ARCH_IDS}


__all__ = ["ARCH_IDS", "ASSIGNED_ARCHS", "SHAPES", "ModelConfig", "ShapeSpec",
           "get_config", "all_configs", "shape_applicable"]
