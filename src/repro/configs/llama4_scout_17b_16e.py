"""Llama-4-Scout-17B-16E [hf:meta-llama]: MoE 16 experts top-1 (early
fusion). Full attention -> long_500k skipped."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e", family="moe", vocab_size=202_048,
    d_model=5_120, n_layers=48, n_heads=40, n_kv_heads=8, d_ff=8_192,
    head_dim=128, rope_base=500_000.0, n_experts=16, top_k=1,
    notes="MoE 16e top-1; ~17B active / ~109B total",
)

REDUCED = CONFIG.replace(vocab_size=503, d_model=64, n_layers=2, n_heads=4,
                         n_kv_heads=2, head_dim=16, d_ff=96, n_experts=4,
                         top_k=1, capacity_factor=8.0, compute_dtype="float32")
