"""Public jit'd wrapper: float activations in, quantize-on-the-fly A8, packed
W4 weights with group-wise scales, float out. Pads every axis to kernel block
multiples."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.quantization import GROUP, QuantizedLinear, quantize_a8
from .kernel import gemv_w4a8_pallas


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "out_dtype", "interpret"))
def gemv_w4a8(x: jax.Array, packed: jax.Array, w_scale: jax.Array,
              *, block_m: int = 8, block_n: int = 256, block_k: int = 512,
              out_dtype=jnp.float32, interpret: bool | None = None) -> jax.Array:
    """x: [..., K] float; packed: [K, N//2] uint8; w_scale: [K//GROUP, N] f32
    (group-wise, see quantization.quantize_w4). Returns [..., N]. Quantizes
    activations per-token to int8 (A8)."""
    interpret = _auto_interpret() if interpret is None else interpret
    lead = x.shape[:-1]
    k = x.shape[-1]
    n = packed.shape[1] * 2
    xf = x.reshape(-1, k)
    m = xf.shape[0]

    xq, xs = quantize_a8(xf)                      # [M, K] int8, [M, 1] f32

    bm = min(block_m, max(8, m))
    pad_m = (-m) % bm
    pad_k = (-k) % block_k
    pad_n = (-n) % block_n
    if pad_m or pad_k:
        xq = jnp.pad(xq, ((0, pad_m), (0, pad_k)))
        xs = jnp.pad(xs, ((0, pad_m), (0, 0)))
    if pad_k or pad_n:
        packed = jnp.pad(packed, ((0, pad_k), (0, pad_n // 2)))
    # group-scale rows for padded K (zero weights x any scale = 0) + padded N
    n_groups = (k + pad_k) // GROUP
    ws = w_scale
    if ws.shape[0] < n_groups:
        ws = jnp.pad(ws, ((0, n_groups - ws.shape[0]), (0, 0)),
                     constant_values=1.0)
    if pad_n:
        ws = jnp.pad(ws, ((0, 0), (0, pad_n)), constant_values=1.0)

    out = gemv_w4a8_pallas(xq, packed, xs, ws, block_m=bm, block_n=block_n,
                           block_k=block_k, out_dtype=out_dtype,
                           interpret=interpret)
    return out[:m, :n].reshape(*lead, n)


def linear_w4a8(x: jax.Array, qw: QuantizedLinear, **kw) -> jax.Array:
    out = gemv_w4a8(x, qw.packed, qw.scale, **kw)
    if qw.bias is not None:
        out = out + qw.bias
    return out
