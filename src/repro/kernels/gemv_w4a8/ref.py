"""Pure-jnp oracle for the W4A8 kernel (dense unpack + int32 einsum)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.quantization import QuantizedLinear, w4a8_matmul_ref


def gemv_w4a8_ref(x, packed, w_scale):
    """Same contract as ops.gemv_w4a8 (float in / float out)."""
    return w4a8_matmul_ref(x, QuantizedLinear(packed=packed, scale=w_scale,
                                              bias=None))
