"""Pallas TPU kernel: W4A8 quantized GEMV/GEMM (paper §IV-B).

TPU realization of the SKV Processor Array's low-precision mode: INT4-packed
weights unpack in VMEM, INT8 activations, INT32 MXU accumulation, group-wise
(128-input-channel) f32 rescale on the way out. The *same* MXU that runs the
(f32) attention kernel runs this — the dual-mode-array story, on a TPU
(DESIGN.md §2).

Grid: ``(M // block_m, N // block_n, K // block_k)`` with sequential K
(``arbitrary``) accumulating into an f32 VMEM scratch tile (int32 partial
sums are rescaled per group *inside* the k-step, so the accumulator carries
the already-dequantized value — this is exactly the SFU's INT32->FXP32
conversion in Fig. 5(c), fused into the MAC loop).

Weights are packed two int4 output-channels per byte along N (matching
:mod:`repro.core.quantization`), so a ``(block_k, block_n)`` logical tile is a
``(block_k, block_n // 2)`` byte tile in HBM — half the weight traffic of
int8, which is the whole point on a bandwidth-bound decode step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quantization import GROUP
from repro.kernels.pallas_compat import CompilerParams


def _kernel(x_ref, wp_ref, xs_ref, ws_ref, o_ref, acc_scr, *, n_k: int,
            group: int):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    packed = wp_ref[...]                                 # [bk, bn//2] uint8
    lo = (packed & 0xF).astype(jnp.int32)
    hi = (packed >> 4).astype(jnp.int32)
    lo = jnp.where(lo >= 8, lo - 16, lo)                 # sign-extend nibbles
    hi = jnp.where(hi >= 8, hi - 16, hi)
    bk = packed.shape[0]
    w = jnp.stack([lo, hi], axis=-1).reshape(bk, -1)     # [bk, bn] int32

    x = x_ref[...].astype(jnp.int32)                     # [bm, bk]
    n_groups = bk // group
    acc = acc_scr[...]
    for g in range(n_groups):                            # static unroll
        sl = slice(g * group, (g + 1) * group)
        part = jax.lax.dot_general(                      # int32 MXU partials
            x[:, sl], w[sl, :], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
        acc = acc + part.astype(jnp.float32) * ws_ref[g, :][None, :]
    acc_scr[...] = acc

    @pl.when(ik == n_k - 1)
    def _finalize():
        out = acc_scr[...] * xs_ref[...].astype(jnp.float32)  # per-token scale
        o_ref[...] = out.astype(o_ref.dtype)


def gemv_w4a8_pallas(x, w_packed, x_scale, w_scale, *, block_m: int = 8,
                     block_n: int = 256, block_k: int = 512,
                     out_dtype=jnp.float32, interpret: bool = False):
    """x: [M, K] int8; w_packed: [K, N//2] uint8; x_scale: [M, 1] f32;
    w_scale: [K//GROUP, N] f32. Returns [M, N]. M/N/K multiples of blocks."""
    m, k = x.shape
    n = w_packed.shape[1] * 2
    n_m, n_n, n_k = m // block_m, n // block_n, k // block_k
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0
    assert block_k % GROUP == 0
    groups_per_block = block_k // GROUP

    return pl.pallas_call(
        functools.partial(_kernel, n_k=n_k, group=GROUP),
        grid=(n_m, n_n, n_k),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda im, jn, ik: (im, ik)),
            pl.BlockSpec((block_k, block_n // 2), lambda im, jn, ik: (ik, jn)),
            pl.BlockSpec((block_m, 1), lambda im, jn, ik: (im, 0)),
            pl.BlockSpec((groups_per_block, block_n),
                         lambda im, jn, ik: (ik, jn)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda im, jn, ik: (im, jn)),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, w_packed, x_scale, w_scale)
