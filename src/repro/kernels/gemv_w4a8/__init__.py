from . import ops, ref
from .ops import gemv_w4a8, linear_w4a8
from .ref import gemv_w4a8_ref

__all__ = ["ops", "ref", "gemv_w4a8", "linear_w4a8", "gemv_w4a8_ref"]
