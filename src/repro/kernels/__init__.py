"""Pallas TPU kernels for the paper's two compute hot-spots: single-pass
decode attention (FXP32 path on the FPGA -> f32 MXU here) and W4A8 GEMV
(the dual-mode array's low-precision mode)."""
