"""Version-compat shims for the Pallas TPU API.

The TPU compiler-params class was renamed across jax releases:
``pltpu.TPUCompilerParams`` (0.4.x) became ``pltpu.CompilerParams`` (newer
releases drop the prefix; some ship both with one deprecated). Kernels import
the resolved name from here so they lower on whichever jax the image bakes in.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")

__all__ = ["CompilerParams"]
