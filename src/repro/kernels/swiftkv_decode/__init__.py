from . import ops, ref
from .ops import swiftkv_decode
from .ref import swiftkv_decode_ref

__all__ = ["ops", "ref", "swiftkv_decode", "swiftkv_decode_ref"]
