"""Public jit'd wrapper for the SwiftKV decode kernel.

Handles GQA grouping, block-size selection, and CPU fallback (interpret
mode) so models can call one function everywhere. KV caches flow through in
their native ``[B, S, Hkv, D]`` layout — the kernel's BlockSpec index maps
tile that layout directly, so there is **no** per-call ``swapaxes`` /
``pad`` (the old wrapper copied the entire cache per layer per decode
step). The flip side of zero-copy is an alignment contract: the cache's
``max_len`` must be divisible by a usable block size at *init* time —
misaligned caches raise instead of silently paying the copy back.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import swiftkv_decode_pallas


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("window", "block_k", "scale",
                                             "exp_mode", "ring", "interpret"))
def swiftkv_decode(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                   lengths: jax.Array, *, window: int | None = None,
                   block_k: int = 512, scale: float | None = None,
                   exp_mode: str = "native", ring: bool = False,
                   k_scale: jax.Array | None = None,
                   v_scale: jax.Array | None = None,
                   interpret: bool | None = None) -> jax.Array:
    """SwiftKV single-pass decode attention (Pallas).

    q: [B, Hq, D]; k_cache/v_cache: [B, S, Hkv, D]; lengths: [B] int32.
    Returns [B, Hq, D]. An exactly-dividing, sublane-aligned (multiple of
    8) ``block_k`` request is honored as-is; a non-dividing request snaps
    down to the largest power-of-two divisor of S, but never silently to a
    degenerate one — a snapped block below 128, or any block that leaves S
    misaligned, raises: allocate the cache block-aligned at ``init_cache``
    instead of paying a pad+copy (or an unaligned whole-cache stream) per
    layer per decode step.

    ``ring=True``: the cache is a ring of R = S slots (newest token at
    ``(lengths-1) % R``); ``lengths`` counts tokens seen, and may exceed S
    once wrapped. The ring streams through the same BlockSpec index maps as
    a linear cache — zero-copy, no host-side unrotate — with per-slot
    positions recovered arithmetically inside the kernel. Requires
    ``window`` (rings only exist for SWA configs).

    ``k_scale`` / ``v_scale``: optional [B, Hkv, S] float dequant scales for
    an int8 cache (``+w4a8`` serving) — streamed blockwise alongside the
    KV tiles and multiplied in VMEM; the alignment contract is unchanged
    (the scale's S axis tiles with the same block size).
    """
    if (k_scale is None) != (v_scale is None):
        raise ValueError("swiftkv_decode: pass both k_scale and v_scale "
                         "or neither")
    if ring and window is None:
        raise ValueError("swiftkv_decode: ring caches are windowed — pass "
                         "window with ring=True")
    b, hq, d = q.shape
    s_len, hkv = k_cache.shape[1], k_cache.shape[2]
    assert hq % hkv == 0
    g = hq // hkv
    scale = float(1.0 / (d ** 0.5)) if scale is None else scale
    interpret = _auto_interpret() if interpret is None else interpret

    bk = min(block_k, s_len)
    requested = bk
    if s_len % bk:
        # any power of two at or below (S & -S) divides S exactly
        bk = min(1 << (bk.bit_length() - 1), s_len & -s_len)
    if s_len % bk or bk % 8 or (bk < 128 and bk != requested):
        raise ValueError(
            f"swiftkv_decode: cache length {s_len} has no usable block for "
            f"block_k={block_k} (best candidate {bk}) — allocate the KV "
            "cache with a block-aligned max_len at init_cache (a multiple "
            "of 128) instead of paying a whole-cache pad+copy, or an "
            "unaligned whole-cache stream, per layer per decode step")
    block_k = bk

    qg = q.reshape(b, hkv, g, d)
    out = swiftkv_decode_pallas(qg, k_cache, v_cache,
                                lengths.astype(jnp.int32),
                                block_k=block_k, window=window, ring=ring,
                                scale=scale, exp_mode=exp_mode,
                                k_scale=k_scale, v_scale=v_scale,
                                interpret=interpret)
    return out.reshape(b, hq, d)
