"""Public jit'd wrapper for the SwiftKV decode kernel.

Handles GQA grouping, cache layout, sequence padding, and CPU fallback
(interpret mode) so models can call one function everywhere.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernel import swiftkv_decode_pallas


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("window", "block_k", "scale",
                                             "exp_mode", "interpret"))
def swiftkv_decode(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                   lengths: jax.Array, *, window: int | None = None,
                   block_k: int = 512, scale: float | None = None,
                   exp_mode: str = "native",
                   interpret: bool | None = None) -> jax.Array:
    """SwiftKV single-pass decode attention (Pallas).

    q: [B, Hq, D]; k_cache/v_cache: [B, S, Hkv, D]; lengths: [B] int32.
    Returns [B, Hq, D].
    """
    b, hq, d = q.shape
    s_len, hkv = k_cache.shape[1], k_cache.shape[2]
    assert hq % hkv == 0
    g = hq // hkv
    scale = float(1.0 / (d ** 0.5)) if scale is None else scale
    interpret = _auto_interpret() if interpret is None else interpret

    block_k = min(block_k, max(128, 1 << (s_len - 1).bit_length()))
    pad = (-s_len) % block_k
    if pad:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))

    qg = q.reshape(b, hkv, g, d)
    kc = jnp.swapaxes(k_cache, 1, 2)   # [B, Hkv, S, D]
    vc = jnp.swapaxes(v_cache, 1, 2)
    out = swiftkv_decode_pallas(qg, kc, vc, lengths.astype(jnp.int32),
                                block_k=block_k, window=window, scale=scale,
                                exp_mode=exp_mode, interpret=interpret)
    return out.reshape(b, hq, d)
