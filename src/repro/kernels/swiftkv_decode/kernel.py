"""Pallas TPU kernel: SwiftKV single-pass decode attention.

TPU adaptation of the paper's per-token pipeline (DESIGN.md §2): the KV cache
streams HBM -> VMEM in ``(block_k, D)`` tiles; the running ``(mu, Z, Y)`` triple
lives in VMEM scratch across sequential grid steps. One pass, exactly-once
reads, no score materialization, deferred division at the last block — the
paper's invariants at MXU-friendly granularity.

Grid: ``(B, Hkv, S // block_k)`` — batch and kv-head parallel, KV blocks
sequential (``arbitrary``). Each program consumes one KV tile for one head
group: all ``G = Hq/Hkv`` query heads of the group share the single KV read
(for MQA this amortizes the whole cache scan over 8 query heads — strictly
better than the paper's per-head duplication).

``lengths`` rides the scalar-prefetch channel: the KV index map *clamps* block
fetches past the valid prefix (re-fetching the last valid tile instead of
streaming garbage), so out-of-range blocks cost no HBM traffic beyond one tile
and are masked out of the math entirely.

``exp_mode="lut"`` reproduces the paper's Eq. 9-10 exponential (32-entry LUT +
linear interpolation) via a one-hot matmul gather that lowers to the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.exp2_lut import LOG2_E, LUT_SIZE, make_lut
from repro.core.swiftkv import NEG_INF
from repro.kernels.pallas_compat import CompilerParams

_LUT_VALS, _LUT_SLOPES = make_lut()


def _exp_lut(x, lut_vals, lut_slopes):
    """exp(x) for x <= 0, Eq. 9-10, MXU-lowerable (one-hot matmul gather).
    ``lut_vals``/``lut_slopes``: [LUT_SIZE] arrays (kernel inputs)."""
    y = x * LOG2_E
    n = jnp.ceil(y)
    f = y - n                                  # (-1, 0]
    u = -f * LUT_SIZE
    idx = jnp.clip(u.astype(jnp.int32), 0, LUT_SIZE - 1)
    f2 = u - idx.astype(x.dtype)
    onehot = (idx[..., None] == jax.lax.broadcasted_iota(
        jnp.int32, (*idx.shape, LUT_SIZE), len(idx.shape))).astype(x.dtype)
    base = onehot @ lut_vals.astype(x.dtype)
    slope = onehot @ lut_slopes.astype(x.dtype)
    frac = base + slope * f2
    # 2^n for n in [-126, 0]: exponent-bias arithmetic, no transcendental
    pow2n = jax.lax.bitcast_convert_type(
        ((jnp.clip(n, -126, 0) + 127.0).astype(jnp.int32)) << 23, jnp.float32)
    return frac * pow2n.astype(x.dtype)


def _kernel(lengths_ref,                     # scalar prefetch [B] int32
            *refs, block_k: int, n_blocks: int, window: int | None,
            scale: float, exp_mode: str, ring: bool, quant: bool):
    refs = list(refs)
    q_ref, k_ref, v_ref = refs[:3]
    refs = refs[3:]
    if quant:
        # int8 KV: per-(row, head, position) f32 dequant scales arrive as
        # (1, 1, block_k) tiles through the same clamped kv index map
        ks_ref, vs_ref = refs[:2]
        refs = refs[2:]
    if exp_mode == "lut":
        lut_ref, o_ref, m_scr, z_scr, y_scr = refs
        exp = functools.partial(_exp_lut, lut_vals=lut_ref[0],
                                lut_slopes=lut_ref[1])
    else:
        o_ref, m_scr, z_scr, y_scr = refs
        exp = jnp.exp
    b = pl.program_id(0)
    i = pl.program_id(2)
    length = lengths_ref[b]

    @pl.when(i == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        z_scr[...] = jnp.zeros_like(z_scr)
        y_scr[...] = jnp.zeros_like(y_scr)

    @pl.when(i * block_k < length)           # blocks past the prefix: no math
    def _update():
        q = q_ref[0, 0].astype(jnp.float32)              # [G, D]
        # KV tiles arrive in the cache-native [B, S, Hkv, D] layout —
        # (1, block_k, 1, D) blocks, no host-side swapaxes/pad copy
        k = jnp.squeeze(k_ref[...], axis=(0, 2)).astype(jnp.float32)
        v = jnp.squeeze(v_ref[...], axis=(0, 2)).astype(jnp.float32)
        if quant:
            # dequantize in registers: int8 tile x per-position scale —
            # the cache itself stays int8 in HBM (the 4x byte win); scales
            # may arrive bf16 (the cache storage dtype) — widen to f32
            k = k * jnp.squeeze(ks_ref[...], axis=(0, 1)).astype(
                jnp.float32)[:, None]
            v = v * jnp.squeeze(vs_ref[...], axis=(0, 1)).astype(
                jnp.float32)[:, None]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        slot = i * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        if ring:
            # ring cache: slot s holds absolute position p - ((p - s) mod R)
            # for p = length - 1 (R = n_blocks * block_k). Validity comes
            # from that position, so a wrapped ring streams through the same
            # BlockSpec index maps untouched — no unrotate copy, and the
            # (mu, Z, Y) fold is order-independent so ring order is exact.
            r = n_blocks * block_k
            p = length - 1
            pos = p - jnp.mod(p - slot, r)
            valid = (pos >= 0) & (pos > p - window)
        else:
            pos = slot
            valid = pos < length
            if window is not None:
                valid &= pos >= length - window
        s = jnp.where(valid, s, NEG_INF)                 # [G, block_k]
        valid_f = valid.astype(jnp.float32)

        m_prev = m_scr[...]                              # [G, 128] (lane-bcast)
        m_blk = jnp.max(s, axis=-1, keepdims=True)       # [G, 1]
        m_new = jnp.maximum(m_prev, m_blk)               # bcast -> [G, 128]
        alpha = exp(m_prev - m_new)                      # (0, 1]
        p = exp(s - m_new[:, :1]) * valid_f              # [G, block_k]
        z_scr[...] = alpha * z_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
        y_scr[...] = alpha[:, :1] * y_scr[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(i == n_blocks - 1)
    def _finalize():
        z = z_scr[:, :1]
        out = jnp.where(z > 0, y_scr[...] / jnp.where(z > 0, z, 1.0), 0.0)
        o_ref[0, 0] = out.astype(o_ref.dtype)


def swiftkv_decode_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                          lengths: jax.Array, *, block_k: int = 512,
                          window: int | None = None, ring: bool = False,
                          scale: float, exp_mode: str = "native",
                          k_scale: jax.Array | None = None,
                          v_scale: jax.Array | None = None,
                          interpret: bool = False) -> jax.Array:
    """q: [B, Hkv, G, D]; k, v: [B, S, Hkv, D] — the **cache-native**
    layout, consumed directly through the BlockSpec index maps (S a
    multiple of block_k); lengths: [B] int32. Returns [B, Hkv, G, D] in
    q.dtype. Feeding the cache layout straight to the grid is what lets the
    ops wrapper stop paying a whole-cache swapaxes+pad copy per layer per
    decode step. ``ring=True`` consumes a ring cache of R = S slots in
    place (slot ``s`` holds position ``p - ((p - s) mod R)``, ``p =
    lengths-1``); only the validity mask changes — the same index maps
    stream the wrapped cache with zero copies. The unwrapped prefix clamp
    still applies: while ``lengths <= S`` blocks past the written prefix
    are neither fetched nor folded.

    ``k_scale`` / ``v_scale``: optional [B, Hkv, S] float (f32 or bf16)
    dequant scales for an **int8** cache — streamed as (1, 1, block_k)
    tiles through the same clamped index map and multiplied into the KV
    tile in VMEM, so the int8 form adds S x itemsize bytes of scale
    traffic per (row, head) against the 3 x S x D bytes it saves on the
    cache itself."""
    bsz, hkv, g, d = q.shape
    s_len = k.shape[1]
    assert s_len % block_k == 0, (s_len, block_k)
    n_blocks = s_len // block_k
    quant = k_scale is not None

    def q_map(b, h, i, lens):
        return (b, h, 0, 0)

    def kv_map(b, h, i, lens):
        # clamp fetches past the valid prefix: no wasted HBM traffic
        last = jnp.maximum(pl.cdiv(lens[b], block_k) - 1, 0)
        return (b, jnp.minimum(i, last), h, 0)

    def sc_map(b, h, i, lens):
        last = jnp.maximum(pl.cdiv(lens[b], block_k) - 1, 0)
        return (b, h, jnp.minimum(i, last))

    in_specs = [
        pl.BlockSpec((1, 1, g, d), q_map),
        pl.BlockSpec((1, block_k, 1, d), kv_map),
        pl.BlockSpec((1, block_k, 1, d), kv_map),
    ]
    operands = [q, k, v]
    if quant:
        in_specs += [pl.BlockSpec((1, 1, block_k), sc_map),
                     pl.BlockSpec((1, 1, block_k), sc_map)]
        operands += [k_scale, v_scale]
    if exp_mode == "lut":
        lut = jnp.stack([jnp.asarray(_LUT_VALS, jnp.float32),
                         jnp.asarray(_LUT_SLOPES, jnp.float32)])
        in_specs.append(pl.BlockSpec((2, LUT_SIZE), lambda b, h, i, lens: (0, 0)))
        operands.append(lut)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(bsz, hkv, n_blocks),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, g, d), q_map),
        scratch_shapes=[
            pltpu.VMEM((g, 128), jnp.float32),   # mu (lane-broadcast)
            pltpu.VMEM((g, 128), jnp.float32),   # Z  (lane-broadcast)
            pltpu.VMEM((g, d), jnp.float32),     # Y
        ],
    )
    kernel = functools.partial(_kernel, block_k=block_k, n_blocks=n_blocks,
                               window=window, scale=scale, exp_mode=exp_mode,
                               ring=ring, quant=quant)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((bsz, hkv, g, d), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lengths, *operands)
