"""Pure-jnp oracle for the SwiftKV decode kernel: naive two-pass softmax
attention (materializes scores — exactly what the kernel avoids)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.swiftkv import NEG_INF


def swiftkv_decode_ref(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                       lengths: jax.Array, *, window: int | None = None,
                       scale: float | None = None) -> jax.Array:
    """q: [B, Hq, D]; caches: [B, S, Hkv, D]; lengths: [B] -> [B, Hq, D]."""
    b, hq, d = q.shape
    s_len, hkv = k_cache.shape[1], k_cache.shape[2]
    g = hq // hkv
    scale = 1.0 / (d ** 0.5) if scale is None else scale

    qg = q.reshape(b, hkv, g, d).astype(jnp.float32)
    kc = k_cache.astype(jnp.float32)
    vc = v_cache.astype(jnp.float32)
    s = jnp.einsum('bhgd,bshd->bhgs', qg, kc) * scale
    t = jnp.arange(s_len)
    valid = t[None, :] < lengths[:, None]                      # [B, S]
    if window is not None:
        valid &= t[None, :] >= lengths[:, None] - window
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(valid[:, None, None, :], p, 0.0)
    out = jnp.einsum('bhgs,bshd->bhgd', p, vc)
    return out.reshape(b, hq, d).astype(q.dtype)
