"""AdamW with f32 moments + master weights, global-norm clipping, cosine
schedule with warmup. Hand-rolled (no optax dependency); moments shard with
the params under FSDP (same pytree structure -> same PartitionSpecs)."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any       # first moment, f32, params-shaped
    nu: Any       # second moment, f32, params-shaped


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params))


def cosine_schedule(step: jax.Array, *, base_lr: float, warmup: int,
                    total: int, min_frac: float = 0.1) -> jax.Array:
    warm = base_lr * (step + 1) / max(warmup, 1)
    t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = base_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(step < warmup, warm, cos).astype(jnp.float32)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(params, grads, state: AdamWState, *, lr: jax.Array,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1, clip_norm: float = 1.0):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    step = state.step + 1
    b1c = 1 - b1 ** step.astype(jnp.float32)
    b2c = 1 - b2 ** step.astype(jnp.float32)

    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
    nu = jax.tree.map(lambda n, g: b2 * n + (1 - b2) * g * g, state.nu, grads)

    def upd(p, m, n):
        mhat = m / b1c
        nhat = n / b2c
        delta = mhat / (jnp.sqrt(nhat) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, AdamWState(step, mu, nu), {"grad_norm": gnorm, "lr": lr}
