from .pipeline import SyntheticTokenStream, batch_for_step

__all__ = ["SyntheticTokenStream", "batch_for_step"]
