"""Deterministic, counted, shardable synthetic token pipeline.

Every batch is a pure function of (seed, step) — restart-after-failure resumes
bit-identically from the checkpointed step with no data-state to persist, and
each data shard derives its slice from the same counter (fault-tolerance lever:
no shuffle buffers to rebuild). The stream is a Zipf-ish unigram mix with
Markov structure so losses move (pure-uniform tokens give flat loss)."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class SyntheticTokenStream:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def batch_for_step(self, step: int) -> dict:
        return batch_for_step(self.vocab_size, self.seq_len, self.global_batch,
                              self.seed, step)


def batch_for_step(vocab: int, seq_len: int, batch: int, seed: int,
                   step: int) -> dict:
    """{tokens, labels}: labels are tokens shifted by one (causal LM)."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    k1, k2 = jax.random.split(key)
    # Zipf-ish unigram distribution (static) + per-position jitter
    ranks = jnp.arange(1, vocab + 1, dtype=jnp.float32)
    logits = -1.1 * jnp.log(ranks)
    toks = jax.random.categorical(k1, logits, shape=(batch, seq_len + 1))
    # weak Markov structure: token_t depends on token_{t-1} parity
    shift = jnp.cumsum(toks % 7, axis=1) % vocab
    toks = (toks + (shift * (jax.random.uniform(k2, toks.shape) < 0.25))) % vocab
    toks = toks.astype(jnp.int32)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def source_for_step(cfg, batch: int, seed: int, step: int) -> jax.Array:
    """Stub-frontend features (vlm patch / audio frame embeddings)."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed ^ 0x5EED), step)
    return jax.random.normal(key, (batch, cfg.source_len, cfg.d_model),
                             jnp.dtype(cfg.compute_dtype)) * 0.02
