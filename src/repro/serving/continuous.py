"""Continuous-batching serving engine: slot pool -> scheduler -> ragged
chunked prefill -> static-shape ragged decode, with **multi-tick decode
blocks** — the per-token host round-trip collapsed into one dispatch per K
tokens.

The jit'd decode program always runs at ``[n_slots]`` batch shape; an
``active`` mask carries which slots hold live requests. Each engine step:

1. **admit** — backfill free slots from the admission queue. Cross-
   attention configs (vlm / audio) also resolve each admitted request's
   **source-KV pool** entry here: an already-resident source id is shared
   by refcount (zero encoder work), a fresh one is ingested once
   (``TransformerLM.ingest_source``) and the slot's ``src_index`` pointed
   at it — before the request's first prefill chunk, whose cross reads
   need the entry resident;
2. **prefill** — every mid-prefill slot advances by one prompt chunk in a
   *single* batched dispatch (``TransformerLM.prefill_chunks_batched``), so
   long prompts never stall in-flight decodes for more than one chunk's
   latency and N prefilling slots cost one host round-trip, not N; a
   request whose final chunk lands is committed (``finalize_slot``), its
   first token sampled from the chunk logits, and its slot joins the active
   set;
3. **decode** — one ``decode_multi`` block of K ragged ticks
   (``lax.scan`` over the decode step with fused sampling and *on-device
   retirement*: per-slot EOS / budget counters flip a row's ``active`` bit
   mid-scan, the freed row parking its writes exactly like any inactive
   row), then one host sync consumes the ``[K, n_slots]`` token block
   post-hoc — per-tick retirement bookkeeping replayed from the block,
   slots released, freed slots backfilled at the next step's admission.

The tick horizon adapts per dispatch::

    K = min(decode_ticks, min remaining budget among active rows)
    K = 1 while admissions or prefill chunks are waiting   # TTFT first
    K floored to a power of two                            # bounded compiles

so at most ``log2(decode_ticks) + 1`` decode programs ever compile and a
freed or newly-prefilled slot joins the batch at the next tick, never K
ticks late.

Greedy outputs are token-for-token identical to per-request
``ServingEngine.generate`` at every tick horizon (tested in
tests/test_serving_continuous.py and tests/test_decode_multi.py): the
scanned block body IS the single-tick ``decode_step(active=...)``, so
chunked prefill reuses the same blockwise ``prefill_attention`` math,
masked-out cache rows are exact no-ops in the (mu, Z, Y) recurrence,
recurrent-state rows (ssm / hybrid) carry through masked ticks unchanged,
and MoE rows use the capacity-free per-row dispatch.

Ring KV configs (``kv_ring`` SWA archs) serve with **O(window) slots**:
``init_cache(chunk=...)`` allocates ``[n_slots, round128(window + chunk),
Hkv, D]`` rings (the chunked-prefill exactness bound ``ring_len >= window
+ chunk - 1`` holds by construction), chunked prefill writes at ``pos %
ring_len`` (a prompt longer than the window wraps over its own
out-of-window entries), parked rows use a per-slot write mask instead of
the reserved tail row, and the decode kernels consume the ring in place.
``report()``'s ``kv_bytes_per_slot`` / ``kv_rows_per_slot`` lines make the
memory win a measured number.

Cross-attention stacks serve through the source-KV pool: slots map to
refcounted, read-only encoder-side K/V entries keyed by source id
(``slot_pool.SourceKVPool`` holds the ledger; ``docs/serving.md`` the
lifecycle). Rows with heterogeneous source lengths coexist in one
static-shape dispatch — each read masks its own entry's ``src_len`` — and
entries are zeroed only when their last holder retires, so slot reuse
never leaks a predecessor's encoder state. ``source_ingests`` /
``source_shares`` in ``report()`` carry the dedup win.

Sampling (temperature > 0) is fused into the jit'd block as seeded per-slot
Gumbel-max (``argmax(logits/T + g)`` with ``g ~ Gumbel(0,1)`` is exactly a
softmax(logits/T) draw). Keys derive from ``(seed, request admission
serial, token index)`` — properties of the *request*, not of the engine's
step counters or the tick horizon — so a request's sampled tokens are
independent of batch composition, of how prefill chunks and decode blocks
interleave, *and of K itself*: the same (seed, trace) replays
token-for-token at decode_ticks 1, 4, or 8.

Host syncs are **block-granular**: a K-block's tokens all become available
at the block's one sync. Per-token timestamps inside a block are attributed
by **even subdivision** of the block's wall span (token at tick t stamped
``block_start + (t+1)/K * span``; labeled ``itl_source: "subdivided"`` in
the report), so ITL percentiles estimate per-token latency instead of
quantizing to ~K-token blocks; ``itl_effective_ms`` (wall seconds per
generated token) remains the exact denominator. TTFT / ITL percentiles come
from fixed-size mergeable log-bucket histograms
(``repro.serving.telemetry.LogHistogram`` — O(1) insert, exact to within
one ~15% bucket), not unbounded sorted lists. Dispatch accounting
(``dispatches``, ``host_syncs``, ``dispatches_per_token``) makes the
round-trip collapse measurable, and ``parked_ticks`` (ticks issued minus
tokens emitted) measures the mid-block-retirement waste the eos-aware
horizon would recover.

Observability: pass ``telemetry=Telemetry()`` to record the structured
lifecycle event stream (enqueue/admit/backfill, source pool ledger events,
prefill_chunk, first_token, decode_block, eos/budget_retire/release) plus
per-block engine gauges, exportable to Chrome/Perfetto trace format — see
``repro.serving.telemetry`` and ``docs/serving.md``. Every emission site is
guarded, so the default (``telemetry=None``) path is the exact
pre-telemetry host loop: byte-identical tokens, zero events.
"""
from __future__ import annotations

import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.transformer import seeded_gumbel_pick

from .audit import EngineAuditor
from .faults import FaultInjected, FaultPlan
from .scheduler import (OverloadConfig, Request, RequestState, Scheduler,
                        DECODING, PREFILLING, QUEUED)
from .slot_pool import KVSlotPool, SourceKVPool
from .telemetry import LogHistogram, Telemetry


def _pct(xs, q):
    """Nearest-rank percentile of an ascending-sorted list: element
    ceil(q*n)-1 (so p50 of [a, b] is a, and p95 only hits the max within
    5% of the tail) — truncation indexing overshoots on short lists.

    ``report()`` now takes its percentiles from the fixed-size
    ``LogHistogram`` stream instead of unbounded sorted lists; this exact
    form remains the reference the histogram is tested against
    (``tests/test_telemetry.py``: agreement within one bucket)."""
    if not xs:
        return None
    return round(float(xs[max(0, math.ceil(q * len(xs)) - 1)]), 4)


class ContinuousBatchingEngine:
    def __init__(self, model, params, *, n_slots: int, max_len: int,
                 chunk: int = 16, eos_id: int | None = None,
                 pad_id: int = 0, temperature: float = 0.0, seed: int = 0,
                 decode_ticks: int = 1, source_len: int | None = None,
                 telemetry: Telemetry | None = None,
                 overload: OverloadConfig | None = None,
                 faults: FaultPlan | None = None,
                 auditor: EngineAuditor | None = None):
        if not getattr(model, "supports_ragged_serving", lambda: False)():
            raise ValueError(
                f"{model.cfg.name}: model does not claim ragged serving "
                "(supports_ragged_serving() is False)")
        if chunk < 1 or max_len % chunk:
            raise ValueError(f"chunk ({chunk}) must divide max_len "
                             f"({max_len}) so padded chunks stay in range")
        if decode_ticks < 1:
            raise ValueError(f"decode_ticks must be >= 1, got {decode_ticks}")
        if getattr(model.cfg, "w4a8_serve", False):
            # +w4a8 config: one-shot W4 weight quantization at engine
            # construction. Deterministic (no RNG), so the seeded-sampling
            # replay contract survives unchanged; the int8 KV side rides on
            # init_cache's dtype default below. The fp32 host loop is
            # untouched — quantization is entirely a params/cache property.
            from repro.models.quantized import quantize_params
            params = quantize_params(params)
        self.model, self.params = model, params
        self.chunk, self.eos_id, self.pad_id = chunk, eos_id, pad_id
        self.temperature = temperature
        self.max_ticks = decode_ticks
        self._t0 = time.perf_counter()          # reset by run()
        # telemetry: self._sink is None when disabled, so every emission
        # site below is a single falsy check — the disabled path runs the
        # exact pre-telemetry host loop (no event objects, no indirection)
        self.tel = telemetry
        if telemetry is None:
            self._sink = None
        else:
            def _sink(kind, t=None, **data):
                telemetry.emit(
                    kind, t=(time.perf_counter() - self._t0
                             if t is None else t), **data)
            self._sink = _sink
        self.pool = KVSlotPool(n_slots, max_len)
        self.sched = Scheduler(self.pool, on_event=self._sink,
                               overload=overload)
        # robustness knobs — all default-off; every consult site below is a
        # single falsy/None check, so the disabled engine runs the exact
        # pre-robustness host loop (same contract as telemetry)
        self.faults = faults          # FaultPlan | None; settable post-warmup
        self.auditor = auditor        # EngineAuditor | None
        self._draining = False
        self._interrupted = False
        self._cancels: set = set()
        self._n_deadlined = 0         # submitted requests carrying an SLO
        self._shed_seen = 0           # sched.shed prefix whose serials are
                                      # already reclaimed
        self.dispatch_retries = 0
        # service-time EWMAs for the submit-time predicted-TTFT gate:
        # per-prefill-chunk dispatch wall and per-request slot-hold time
        self._chunk_s = 0.0
        self._svc_s = 0.0
        self._prefill_batched = jax.jit(model.prefill_chunks_batched,
                                        donate_argnums=(2,))
        self._finalize = jax.jit(model.finalize_slot, donate_argnums=(0,))
        self._release = jax.jit(model.release_slot, donate_argnums=(0,))

        # cross-attention stacks (vlm / audio): a second, refcounted pool
        # holds the encoder-side K/V, keyed by source id — ingested once at
        # admission, shared read-only by every slot whose request presents
        # the same id, zeroed when the last holder retires. n_entries ==
        # n_slots, so an entry is always available when a slot is
        # (each live request holds at most one reference).
        from repro.models.api import needs_source
        cfg = model.cfg
        self.needs_source = needs_source(cfg)
        self.src_pool = None
        if self.needs_source:
            self.src_max = source_len or cfg.source_len
            self.src_pool = SourceKVPool(n_slots, self.src_max,
                                         on_event=self._sink)
            self._srcs: dict = {}           # rid -> held source id
            self._ingest = jax.jit(model.ingest_source, donate_argnums=(2,))
            self._assign = jax.jit(model.assign_source, donate_argnums=(0,))
            self._src_release = jax.jit(model.release_source,
                                        donate_argnums=(0,))

        # sampler keys: (seed, request admission serial, token index) —
        # request-intrinsic, so a draw can't depend on batch composition,
        # on how the scheduler interleaved prefill chunks with decode
        # blocks, or on the tick horizon K
        self._base_key = jax.random.PRNGKey(seed)
        self._decode_fns: dict = {}     # (tick horizon K, poisoned) -> jit

        def _prefill_pick(logits_row, serial):
            # first token off a finalized prefill: [V] -> scalar int32.
            # Token index 0 of the SAME (seed, serial, idx) key stream the
            # fused decode draws tokens 1..n from (seeded_gumbel_pick is
            # the single shared definition)
            if temperature == 0.0:
                return jnp.argmax(logits_row).astype(jnp.int32)
            return seeded_gumbel_pick(self._base_key, logits_row, serial,
                                      jnp.int32(0), temperature)
        self._prefill_pick = jax.jit(_prefill_pick)

        self.cache = model.init_cache(
            n_slots, max_len,
            self.src_max if self.needs_source else None,
            n_sources=n_slots if self.needs_source else None,
            chunk=chunk)
        if cfg.kv_ring and cfg.window and "k" in self.cache:
            # ring-prefill exactness bound: a chunk's later tokens may
            # overwrite ring slots its earlier queries still need unless
            # the overwritten positions are already outside every live
            # window — guaranteed iff ring_len >= window + chunk - 1.
            # init_cache(chunk=...) sizes the ring as round128(window +
            # chunk) precisely so this holds by construction (degenerating
            # to the never-wrapping full cache when that reaches max_len),
            # so the check below is a safety invariant, not a user-facing
            # constraint.
            ring_len = int(self.cache["k"].shape[2])
            if ring_len < max_len and chunk > ring_len - cfg.window + 1:
                raise ValueError(
                    f"chunk ({chunk}) too large for the ring: a "
                    f"{ring_len}-slot ring over window {cfg.window} "
                    f"supports chunks up to {ring_len - cfg.window + 1} "
                    "(ring_len >= window + chunk - 1 keeps chunked "
                    "prefill exact under wraparound)")
        # gauge precompute: self-attention KV bytes per (slot, row) — the
        # live-KV gauge is sum_over_active(min(len, rows)) * this
        self._kv_rows = (int(self.cache["k"].shape[2])
                         if "k" in self.cache else 0)
        kv_self = [self.cache[k] for k in ("k", "v", "k_scale", "v_scale")
                   if k in self.cache]
        self._kv_row_bytes = (
            sum(int(a.size) * a.dtype.itemsize for a in kv_self)
            // (n_slots * self._kv_rows) if self._kv_rows else 0)
        # streaming latency stats: fixed-size mergeable log-bucket
        # histograms (seconds), reset per run — report() percentiles come
        # from these, not from unbounded per-token lists
        self.hist_ttft = LogHistogram()
        self.hist_itl = LogHistogram()
        self.tok = np.full((n_slots,), pad_id, np.int32)
        self.active = np.zeros((n_slots,), bool)
        # per-slot sampler / retirement state, mirrored on device per block:
        # admission serial of the occupying request, tokens emitted so far
        # (the next draw's token index), and the request's total allowance
        self.serial = np.zeros((n_slots,), np.int32)
        self.emitted = np.zeros((n_slots,), np.int32)
        self.budget = np.zeros((n_slots,), np.int32)
        self._serials: dict = {}        # rid -> serial, mid-prefill only
        self._serial_ctr = 0
        # EWMA of per-tick wall time, measured off each block dispatch —
        # used to cap the horizon so a block doesn't overshoot the next
        # timed arrival when a free slot is waiting for it
        self._tick_s = 0.0
        self._zero_counters()

    def _zero_counters(self) -> None:
        # occupancy / utilization / dispatch-accounting counters
        self.decode_steps = 0           # executed ticks with >=1 live row
        self.decode_dispatches = 0      # decode block programs launched
        self.prefill_chunks = 0         # chunk advances (rows, not launches)
        self.prefill_dispatches = 0     # batched prefill programs launched
        self.active_row_steps = 0
        self.dispatches = 0             # every jit'd program launch
        self.host_syncs = 0             # blocking device -> host transfers
        self.issued_ticks = 0           # K * active rows, per decode block
        self.parked_ticks = 0           # issued - emitted: mid-block-retire
                                        # waste (eos-aware-horizon target)

    # ---- intake -----------------------------------------------------------
    def submit(self, request: Request, now: float = 0.0) -> RequestState:
        """Typed submit-time validation: every constraint the trace can
        violate terminates as a structured rejection (``code`` +
        ``finish_reason``) at submit, never an assert mid-trace. Overload
        decisions (drain in progress, bounded queue, unattainable TTFT
        deadline) terminate as ``shed`` instead — the request was feasible,
        the engine chose to drop it."""
        reject = shed = None
        if len(request.prompt) > self.pool.capacity:
            reject = ("prompt_too_long",
                      f"rejected: prompt of {len(request.prompt)} tokens > "
                      f"slot capacity {self.pool.capacity}")
        elif self.needs_source:
            if (request.source is not None
                    and len(request.source) > self.src_max):
                reject = ("source_too_long",
                          f"rejected: source of {len(request.source)} rows "
                          f"> source-KV pool rows {self.src_max}")
            elif request.source is None and request.source_id is not None:
                # a shared id must be ingestable by whichever holder
                # arrives first — an id with no features would poison the
                # entry (src_len 0) for every later sharer, so it is a
                # contract violation, rejected here rather than silently
                # decoding sourceless
                reject = ("source_id_without_source",
                          "rejected: source_id "
                          f"{request.source_id!r} without source features "
                          "(a shared entry must be ingestable by its "
                          "first holder)")
        if reject is None:
            if self._draining:
                shed = ("drain", "shed: engine is draining")
            elif (request.ttft_deadline_s is not None
                  and self.sched.overload is not None):
                est = self._predict_ttft(request)
                if est is not None and est > request.ttft_deadline_s:
                    shed = ("ttft_unattainable",
                            f"shed: predicted TTFT {est:.4f}s > deadline "
                            f"{request.ttft_deadline_s:.4f}s")
        state = self.sched.submit(request, now, reject=reject, shed=shed)
        if state.status == QUEUED:
            # admission order is FIFO over submission order, so the serial
            # is a deterministic property of the trace
            self._serials[state.rid] = self._serial_ctr
            self._serial_ctr += 1
            if (request.ttft_deadline_s is not None
                    or request.deadline_s is not None):
                self._n_deadlined += 1
        self._sync_shed_serials()
        return state

    def _sync_shed_serials(self) -> None:
        """Reclaim sampler serials of requests shed while queued (the
        bounded queue's shed-oldest policy evicts inside the scheduler, so
        the engine reconciles against the shed list's new suffix)."""
        shed = self.sched.shed
        while self._shed_seen < len(shed):
            self._serials.pop(shed[self._shed_seen].rid, None)
            self._shed_seen += 1

    def _predict_ttft(self, request: Request) -> float | None:
        """EWMA-based TTFT estimate for an arriving request: queue wait
        (queued-ahead waves times the per-request slot-hold EWMA, plus one
        wave when no slot is free) plus its own chunked prefill (chunks
        times the per-chunk-dispatch EWMA). ``None`` until the engine has
        served enough traffic to have both EWMAs — the gate never sheds on
        a cold engine."""
        if self._chunk_s == 0.0 or self._svc_s == 0.0:
            return None
        waves = len(self.sched.queue) / self.pool.n_slots
        if self.pool.n_free == 0:
            waves += 1.0
        chunks = math.ceil(len(request.prompt) / self.chunk)
        return waves * self._svc_s + chunks * self._chunk_s

    # ---- overload / lifecycle control --------------------------------------
    def cancel(self, rid) -> None:
        """Client cancellation: applied at the next step boundary — a
        queued request sheds (``cancelled``), an in-flight one retires with
        its partial tokens (``finish_reason`` / ``code`` ``cancelled``) and
        its slot + source reference reclaimed. Unknown or already-finished
        rids are dropped silently (cancellation races completion)."""
        self._cancels.add(rid)

    def drain(self) -> None:
        """Graceful shutdown: stop admitting (later submits shed with code
        ``drain``), shed everything still queued at the next step boundary,
        and let in-flight requests finish naturally. ``run()`` then returns
        once the last in-flight request retires, flushing telemetry."""
        self._draining = True
        if self._sink is not None:
            self._sink("drain", t=time.perf_counter() - self._t0,
                       queued=len(self.sched.queue),
                       in_flight=len(self.sched.prefilling)
                       + len(self.sched.decoding))

    def _enforce_control(self, now: float) -> None:
        """Step-boundary control actions: drain sheds the queue,
        cancellations and expired deadlines shed queued requests / retire
        in-flight ones with slot + source reclaim. Only runs when one of
        the three triggers is live (``step`` guards the call), so the
        default path costs nothing."""
        if self._draining:
            for st in list(self.sched.queue):
                self.sched.shed_queued(st, "drain", now,
                                       detail="shed: engine draining")
        if self._cancels:
            live = {st.rid: st for st in list(self.sched.queue)
                    + list(self.sched.prefilling)
                    + list(self.sched.decoding.values())}
            for rid in list(self._cancels):
                st = live.get(rid)
                if st is not None:
                    if st.status == QUEUED:
                        self.sched.shed_queued(st, "cancelled", now,
                                               detail="shed: cancelled by "
                                                      "client")
                    else:
                        self._reclaim(st, "cancelled", now,
                                      detail="cancelled by client")
                self._cancels.discard(rid)
        if self._n_deadlined:
            for st in list(self.sched.queue):
                r = st.request
                missed = ((r.deadline_s is not None
                           and now - st.t_submit > r.deadline_s)
                          or (r.ttft_deadline_s is not None
                              and now - st.t_submit > r.ttft_deadline_s))
                if missed:
                    self.sched.shed_queued(
                        st, "deadline", now,
                        detail=f"shed: deadline expired after "
                               f"{now - st.t_submit:.4f}s in queue")
            for st in (list(self.sched.prefilling)
                       + list(self.sched.decoding.values())):
                r = st.request
                missed = ((r.deadline_s is not None
                           and now - st.t_submit > r.deadline_s)
                          or (st.t_first is None
                              and r.ttft_deadline_s is not None
                              and now - st.t_submit > r.ttft_deadline_s))
                if missed:
                    self._reclaim(st, "deadline", now,
                                  detail=f"deadline missed after "
                                         f"{now - st.t_submit:.4f}s")
        self._sync_shed_serials()

    def _reclaim(self, state: RequestState, code: str, now: float, *,
                 error: bool = False, detail: str | None = None,
                 device: bool = True) -> int:
        """Stop a slot-holding request before its natural end and reclaim
        everything it owns: the scheduler records the typed terminal state
        (RETIRED with partial tokens, or ERRORED when ``error``), the slot
        returns to the free list, its device rows reset, and its source-KV
        reference dropped (entry zeroed when this was the last holder) —
        the same reclaim order as normal retirement in ``_emit``.
        ``device=False`` skips the device dispatches (KeyboardInterrupt
        unwinding: the cache may hold a donated buffer mid-dispatch, so
        only host ledgers are cleaned)."""
        serial = self._serials.get(state.rid)
        was_prefilling = state.status == PREFILLING
        slot = self.sched.abort(state, code, now, error=error, detail=detail)
        if was_prefilling:
            self._serials.pop(state.rid, None)
        else:
            serial = int(self.serial[slot])
        if device:
            self.cache = self._release(self.cache, jnp.int32(slot))
            self.dispatches += 1
        if self.needs_source and state.rid in self._srcs:
            freed = self.src_pool.release(self._srcs.pop(state.rid),
                                          owner=state.rid)
            if freed is not None and device:
                self.cache = self._src_release(self.cache, jnp.int32(freed))
                self.dispatches += 1
        if self._sink is not None:
            self._sink("error_retire" if error else "abort", t=now,
                       rid=state.rid, slot=slot, serial=serial, code=code,
                       n_tokens=len(state.tokens))
            self._sink("release", t=now, rid=state.rid, slot=slot,
                       serial=serial)
        self.active[slot] = False
        self.tok[slot] = self.pad_id
        self.budget[slot] = 0
        self._note_service(state, now)
        return slot

    def _note_service(self, state: RequestState, now: float) -> None:
        # slot-hold EWMA feeding the predicted-TTFT gate; host float math
        # only, so it runs unconditionally
        if state.t_admit is None:
            return
        hold = max(0.0, now - state.t_admit)
        self._svc_s = (hold if self._svc_s == 0.0
                       else 0.5 * self._svc_s + 0.5 * hold)

    def _quarantine(self, slot: int, now: float) -> None:
        """A decode row reported the ``-2`` non-finite-logits sentinel:
        quarantine exactly that request — typed ERRORED terminal state,
        slot + source reclaimed — while every other stream proceeds
        untouched (their rows never read this slot's state)."""
        state = self.sched.decoding[slot]
        self._reclaim(state, "nonfinite_logits", now, error=True,
                      detail="errored: non-finite logits row (quarantined "
                             "by the on-device finite check)")

    def warmup(self) -> "ContinuousBatchingEngine":
        """Compile the chunk / finalize / decode / release programs with a
        throwaway request whose budget (2*decode_ticks, prioritized over
        prompt length when the pool is small) walks the adaptive horizon
        down through every power-of-two K <= decode_ticks — on a pool too
        small to ever reach the larger horizons, whatever residual K a real
        trace *can* reach still compiles on its first use. ``run`` drops
        finished-traffic stats at entry so reports cover real traffic only;
        the warmup request consumes exactly one sampler serial, so two
        warmed-up engines with the same seed still draw identical
        streams."""
        m_want = 2 * self.max_ticks     # walks K = max_ticks, ..., 2, 1
        p = max(1, min(self.chunk + 1, self.pool.capacity - m_want))
        m = max(2, min(m_want, self.pool.capacity - p))
        src = (np.zeros((self.src_max, self.model.cfg.d_model), np.float32)
               if self.needs_source else None)   # compiles ingest/assign too
        # the fault plan must not burn its faults on warmup traffic
        faults, self.faults = self.faults, None
        try:
            self.run([Request(prompt=np.zeros(p, np.int32), max_new_tokens=m,
                              rid="__warmup__", source=src)])
        finally:
            self.faults = faults
        return self

    # ---- decode program per tick horizon ----------------------------------
    def _decode_fn(self, k: int, poisoned: bool = False):
        """jit'd K-tick block. At most log2(max_ticks)+1 of these ever
        compile (the horizon is floored to a power of two). ``poisoned``
        compiles the fault-injection variant taking a [n_slots] bool mask
        whose rows get NaN logits each tick — a separate cache key, so
        fault-free runs never pay for the extra argument."""
        fn = self._decode_fns.get((k, poisoned))
        if fn is None:
            model, eos, temp = self.model, self.eos_id, self.temperature
            key = self._base_key

            if poisoned:
                def block(params, tok, cache, active, budget, serials,
                          emitted, poison):
                    toks, _, _, cache = model.decode_multi(
                        params, tok, cache, active, budget, serials,
                        emitted, k, eos_id=eos, temperature=temp,
                        rng_key=key, poison=poison)
                    return toks, cache
            else:
                def block(params, tok, cache, active, budget, serials,
                          emitted):
                    toks, _, _, cache = model.decode_multi(
                        params, tok, cache, active, budget, serials,
                        emitted, k, eos_id=eos, temperature=temp,
                        rng_key=key)
                    return toks, cache
            fn = jax.jit(block, donate_argnums=(2,))
            self._decode_fns[(k, poisoned)] = fn
        return fn

    def _tick_horizon(self, now: float | None = None,
                      deadline: float | None = None) -> int:
        """K = min(decode_ticks, min remaining budget among active rows),
        forced to 1 while prefill chunks are waiting (a mid-prefill slot
        must advance every tick and join the batch the tick its final chunk
        lands — TTFT is not sacrificed to throughput), floored to a power
        of two to bound the number of compiled programs.

        A non-empty admission queue does *not* force K=1: ``admit()`` ran
        at the top of this step, so queued requests mean every slot is
        busy, and the min-remaining-budget cap already ends the block at
        exactly the next scheduled (max-token) retirement — the freed slot
        backfills at the following step, never K ticks late.

        ``deadline``: engine-clock time of the next *timed arrival while a
        slot sits free* (run() passes it) — the horizon is additionally
        capped so the block ends by then (estimated via the per-tick EWMA),
        keeping an arriving request's TTFT flat in K instead of paying up
        to K-1 ticks of block drain before it can even submit. The one
        residual trade: an unpredictable mid-block EOS costs up to K-1
        parked ticks before its slot backfills."""
        if self.max_ticks == 1 or self.sched.prefilling:
            return 1
        rem = min(s.remaining for s in self.sched.decoding.values())
        k = max(1, min(self.max_ticks, rem))
        if (deadline is not None and now is not None and self._tick_s > 0):
            k = max(1, min(k, int((deadline - now) / self._tick_s)))
        if self._n_deadlined and now is not None and self._tick_s > 0:
            # an in-flight total deadline also caps the horizon: the block
            # should end near the deadline so enforcement (step-boundary)
            # doesn't overshoot by up to K-1 ticks of dead work
            for st in self.sched.decoding.values():
                d = st.request.deadline_s
                if d is not None:
                    left = st.t_submit + d - now
                    k = max(1, min(k, max(1, int(left / self._tick_s))))
        return 1 << (k.bit_length() - 1)

    # ---- one engine step --------------------------------------------------
    def step(self, now: float | None = None,
             deadline: float | None = None) -> bool:
        """Admit + advance every prefilling slot one chunk (one batched
        dispatch) + one K-tick decode block. ``deadline``: next timed
        arrival while a slot is free (caps the horizon — see
        ``_tick_horizon``). Returns False when nothing was left to do."""
        now = (time.perf_counter() - self._t0) if now is None else now
        if self._draining or self._cancels or self._n_deadlined:
            self._enforce_control(now)
        newly = self.sched.admit(now)
        if self.needs_source:
            # source ingest happens AT admission, before the request's
            # first prefill chunk — the chunk's cross reads need the
            # entry resident (whisper-style decoders cross-attend in
            # every layer from chunk 0)
            for st in newly:
                if (self.faults is not None
                        and self.faults.take_ingest(st.rid) is not None):
                    # injected ingest failure: quarantine before any device
                    # write — the slot returns to the free list this step
                    if self._sink is not None:
                        self._sink("fault", t=now, rid=st.rid,
                                   fault="ingest_fail")
                    self._reclaim(st, "source_ingest_failed", now,
                                  error=True,
                                  detail="errored: source-KV ingest failed")
                    continue
                self._acquire_source(st)

        if self.sched.prefilling:
            self._advance_prefills()

        if not self.active.any():
            return self.sched.pending()

        k = self._tick_horizon(now, deadline)
        live_slots = np.flatnonzero(self.active)     # rows at dispatch time
        blk_idx = self.decode_dispatches
        poison = None
        if self.faults is not None:
            d = self.faults.take("tick_delay", block=blk_idx)
            if d is not None:
                if self._sink is not None:
                    self._sink("fault", t=time.perf_counter() - self._t0,
                               block=blk_idx, fault="tick_delay",
                               delay_s=d.delay_s)
                time.sleep(d.delay_s)
            while True:
                try:
                    # fires BEFORE the jit call: the donated cache was
                    # never consumed, so re-dispatching is safe
                    self.faults.raise_if("dispatch_fail", block=blk_idx)
                    break
                except FaultInjected:
                    self.dispatch_retries += 1
                    if self._sink is not None:
                        self._sink("fault",
                                   t=time.perf_counter() - self._t0,
                                   block=blk_idx, fault="dispatch_fail",
                                   retry=self.dispatch_retries)
            hits = self.faults.take_poison(
                {st.rid: len(st.tokens)
                 for st in self.sched.decoding.values()}, blk_idx)
            if hits:
                mask = np.zeros((self.pool.n_slots,), bool)
                for slot, st in self.sched.decoding.items():
                    if st.rid in hits:
                        mask[slot] = True
                poison = jnp.asarray(mask)
                if self._sink is not None:
                    self._sink("fault", t=time.perf_counter() - self._t0,
                               block=blk_idx, fault="poison_nan",
                               rids=list(hits))
        t_dispatch = time.perf_counter()
        if poison is None:
            toks, self.cache = self._decode_fn(k)(
                self.params, jnp.asarray(self.tok), self.cache,
                jnp.asarray(self.active), jnp.asarray(self.budget),
                jnp.asarray(self.serial), jnp.asarray(self.emitted))
        else:
            toks, self.cache = self._decode_fn(k, poisoned=True)(
                self.params, jnp.asarray(self.tok), self.cache,
                jnp.asarray(self.active), jnp.asarray(self.budget),
                jnp.asarray(self.serial), jnp.asarray(self.emitted), poison)
        self.decode_dispatches += 1
        self.dispatches += 1
        rows = np.asarray(toks)                  # [K, n_slots]; the ONE sync
        self.host_syncs += 1
        # the block's tokens all became available at this one sync; stamps
        # inside the block are attributed by even subdivision of its wall
        # span (itl_source: "subdivided" in report())
        now_blk = time.perf_counter() - self._t0
        blk_start = t_dispatch - self._t0
        span = now_blk - blk_start
        per_tick = span / k
        self._tick_s = (per_tick if self._tick_s == 0.0
                        else 0.5 * self._tick_s + 0.5 * per_tick)
        emitted_blk = 0
        quarantined = []
        for t in range(k):
            live = rows[t] >= 0                  # -1 marks parked rows
            bad = rows[t] == -2                  # quarantine sentinel: the
            if not live.any() and not bad.any():  # row's logits went NaN/inf
                break                            # all rows retired mid-block
            stamp = blk_start + (t + 1) * per_tick   # == now_blk at t == k-1
            if live.any():
                self.decode_steps += 1
                self.active_row_steps += int(live.sum())
                emitted_blk += int(live.sum())
                for slot in np.flatnonzero(live):
                    state = self.sched.decoding[int(slot)]
                    self.pool.advance(int(slot))
                    self._emit(state, int(rows[t, slot]), stamp)
            for slot in np.flatnonzero(bad):
                quarantined.append(int(slot))
                self._quarantine(int(slot), stamp)
        issued = k * len(live_slots)
        self.issued_ticks += issued
        self.parked_ticks += issued - emitted_blk
        if self._sink is not None:
            extra = {"quarantined": quarantined} if quarantined else {}
            self._sink(
                "decode_block", t=now_blk, block=blk_idx, k=k,
                dur=round(span, 6), emitted=emitted_blk,
                parked=issued - emitted_blk,
                slots=[int(s) for s in live_slots],
                serials=[int(self.serial[s]) for s in live_slots],
                tokens_per_slot=[int((rows[:k, s] >= 0).sum())
                                 for s in live_slots], **extra)
            self._sample_gauges(now_blk, blk_idx, k, issued - emitted_blk)
        if self.auditor is not None:
            self.auditor.maybe_check(self)
        return True

    def _sample_gauges(self, t: float, block: int, k: int,
                       parked: int) -> None:
        """Engine gauges, sampled at each decode block's sync: occupancy /
        queue / free-slot state, live KV bytes (rows actually holding
        committed context, not the preallocated pool), the chosen tick
        horizon, and this block's parked-tick waste. Rendered as counter
        tracks in the Perfetto export."""
        g = dict(
            active_slots=int(self.active.sum()),
            free_slots=self.pool.n_free,
            queue_depth=len(self.sched.queue),
            prefilling=len(self.sched.prefilling),
            occupancy=round(self.pool.n_used / self.pool.n_slots, 3),
            tick_k=k,
            parked_ticks_block=parked,
            parked_ticks_total=self.parked_ticks,
            kv_bytes_live=self._kv_row_bytes * sum(
                min(self.pool.length(int(s)), self._kv_rows)
                for s in np.flatnonzero(self.active)),
        )
        if self.src_pool is not None:
            g["src_entries_used"] = self.src_pool.n_used
            g["src_refs"] = sum(self.src_pool.refcount(e)
                                for e in range(self.src_pool.n_entries))
        self._sink("gauges", t=t, block=block, **g)

    def _acquire_source(self, st: RequestState) -> None:
        """Resolve a newly admitted request's source-KV pool entry: bump an
        existing entry's refcount when its source id is already resident
        (no encoder work at all — the dedup win), else take a fresh entry
        and ingest the padded source once (one dispatch: encoder for
        audio, per-layer cross K/V projections for vlm). Either way the
        slot's ``src_index`` is pointed at the entry. A request without a
        source still takes an entry; its ``src_len`` stays 0, so every
        cross read masks to an exact zero."""
        req = st.request
        sid = (req.source_id if req.source_id is not None
               else ("__rid__", st.rid))
        entry, fresh = self.src_pool.acquire(sid, owner=st.rid)
        assert entry is not None, "source pool exhausted with a free slot"
        self._srcs[st.rid] = sid
        if fresh and req.source is not None:
            cfg = self.model.cfg
            padded = np.zeros((self.src_max, cfg.d_model), np.float32)
            padded[:len(req.source)] = req.source
            self.cache = self._ingest(self.params, jnp.asarray(padded),
                                      self.cache, jnp.int32(entry),
                                      jnp.int32(len(req.source)))
            self.dispatches += 1
        # fresh + no source: the entry's rows and src_len are already zero
        # (init / release_source), which IS the empty-source state
        self.cache = self._assign(self.cache, jnp.int32(st.slot),
                                  jnp.int32(entry))
        self.dispatches += 1

    def _advance_prefills(self) -> None:
        """One batched dispatch advancing *all* mid-prefill slots one chunk
        (``prefill_chunks_batched``); finalized requests sample their first
        token from their chunk-logits row (a scalar int32 transfer, never
        the [V] logits)."""
        states = list(self.sched.prefilling)
        n = self.pool.n_slots
        toks = np.full((n, self.chunk), self.pad_id, np.int32)
        slots = np.zeros((n,), np.int32)
        offs = np.zeros((n,), np.int32)
        lasts = np.zeros((n,), np.int32)
        valid = np.zeros((n,), bool)
        sizes = [0] * n
        for i, st in enumerate(states):
            prompt = st.request.prompt
            off = st.prefilled
            part = prompt[off:off + self.chunk]
            toks[i, :part.size] = part
            slots[i], offs[i] = st.slot, off
            lasts[i] = min(self.chunk - 1, max(0, len(prompt) - 1 - off))
            valid[i] = True
            sizes[i] = int(part.size)
        blk_idx = self.prefill_dispatches
        t_dispatch = time.perf_counter()
        logits, self.cache = self._prefill_batched(
            self.params, jnp.asarray(toks), self.cache, jnp.asarray(slots),
            jnp.asarray(offs), jnp.asarray(lasts), jnp.asarray(valid))
        self.prefill_dispatches += 1
        self.dispatches += 1
        self.prefill_chunks += len(states)
        if self._sink is not None:
            # one slice per advanced slot, sharing the batched dispatch's
            # host-side span (the program itself retires asynchronously —
            # its device time is hidden inside the next blocking sync)
            t_done = time.perf_counter()
            dur = round(t_done - t_dispatch, 6)
            t_ev = t_done - self._t0
            for i, st in enumerate(states):
                self._sink("prefill_chunk", t=t_ev, rid=st.rid,
                           slot=st.slot, serial=self._serials.get(st.rid),
                           block=blk_idx, offset=int(offs[i]),
                           n_tokens=sizes[i], dur=dur)
        for i, st in enumerate(states):
            prompt = st.request.prompt
            st.prefilled = min(st.prefilled + self.chunk, len(prompt))
            if st.prefilled < len(prompt):
                continue   # non-final chunk: logits row never leaves device
            # final chunk: commit the slot, sample the first token on device
            self.cache = self._finalize(self.cache, jnp.int32(st.slot),
                                        len(prompt))
            self.dispatches += 1
            self.sched.start_decoding(st)
            self.serial[st.slot] = self._serials.pop(st.rid)
            self.budget[st.slot] = st.request.max_new_tokens
            tok0 = int(self._prefill_pick(logits[i],
                                          jnp.int32(self.serial[st.slot])))
            self.dispatches += 1
            self.host_syncs += 1
            t_tok0 = time.perf_counter() - self._t0
            # admit -> first-token wall per chunk (includes the decode
            # blocks interleaved between chunks — the realistic under-load
            # cost the predicted-TTFT gate needs); host float math only
            per_chunk = (max(0.0, t_tok0 - st.t_admit)
                         / max(1, math.ceil(len(prompt) / self.chunk)))
            self._chunk_s = (per_chunk if self._chunk_s == 0.0
                             else 0.5 * self._chunk_s + 0.5 * per_chunk)
            if self._sink is not None:
                self._sink("first_token", t=t_tok0, rid=st.rid,
                           slot=st.slot, serial=int(self.serial[st.slot]),
                           token=tok0)
            self._emit(st, tok0, t_tok0)

    def _emit(self, state: RequestState, token: int, now: float) -> None:
        # ``now``: the token's attributed timestamp — exact for prefill
        # first tokens (stamped at their sync) and single-tick blocks,
        # evenly subdivided across a multi-tick block's wall span otherwise
        if state.token_times:
            self.hist_itl.add(max(0.0, now - state.token_times[-1]))
        state.tokens.append(token)
        state.token_times.append(now)
        if state.t_first is None:
            state.t_first = now
            self.hist_ttft.add(max(0.0, now - state.t_submit))
        done = (self.eos_id is not None and token == self.eos_id)
        if done or len(state.tokens) >= state.request.max_new_tokens:
            # mirrors decode_multi's on-device retirement exactly: the
            # device flipped this row's active bit at the same tick
            reason = "eos" if done else "max_tokens"
            if self._sink is not None:
                self._sink("eos" if done else "budget_retire", t=now,
                           rid=state.rid, slot=state.slot,
                           serial=int(self.serial[state.slot]),
                           n_tokens=len(state.tokens))
            slot = self.sched.retire(state, reason, now)
            self.cache = self._release(self.cache, jnp.int32(slot))
            self.dispatches += 1
            if self.needs_source:
                # drop the source reference; zero the entry only when this
                # was the last holder (other slots may still be decoding
                # against the same source id)
                freed = self.src_pool.release(self._srcs.pop(state.rid),
                                              owner=state.rid)
                if freed is not None:
                    self.cache = self._src_release(self.cache,
                                                   jnp.int32(freed))
                    self.dispatches += 1
            if self._sink is not None:
                self._sink("release", t=now, rid=state.rid, slot=slot,
                           serial=int(self.serial[slot]))
            self.active[slot] = False
            self.tok[slot] = self.pad_id
            self.budget[slot] = 0
            self._note_service(state, now)
        else:
            self.active[state.slot] = True
            self.tok[state.slot] = token
            self.emitted[state.slot] = len(state.tokens)

    # ---- drive a whole trace ----------------------------------------------
    def run(self, requests: list[Request] | None = None) -> dict:
        """Drive until every request retires. Each request is submitted once
        the wall clock passes its ``Request.arrival`` offset (0.0 on every
        request = a fully backlogged throughput run); when the engine is
        idle it sleeps until the next arrival, so TTFT measures from the
        request's actual submission.

        ``drain()`` (from a signal handler or another coroutine) makes the
        run finish early but cleanly: queued and not-yet-due requests shed
        with code ``drain``, in-flight ones finish naturally. A
        ``KeyboardInterrupt`` is the abrupt form: the in-flight block that
        already dispatched completes (the interrupt is caught at the loop
        boundary), queued + waiting requests shed, slot-holding requests
        retire with their partial tokens (code ``interrupt``) via
        host-only reclaim (the device cache may hold a donated buffer
        mid-dispatch), telemetry flushes, and the report is returned with
        ``interrupted: true`` instead of the exception unwinding through a
        half-consistent engine."""
        # per-run stats: an engine is reusable (warmup, successive traces),
        # so drop finished-traffic history before timing starts
        self.sched.reset_stats()
        self.pool.reset_stats()
        if self.src_pool is not None:
            self.src_pool.reset_stats()
        self._zero_counters()
        self.hist_ttft.reset()
        self.hist_itl.reset()
        self._shed_seen = 0
        self._draining = False
        self._interrupted = False
        self._cancels.clear()
        self.dispatch_retries = 0
        if self.auditor is not None:
            self.auditor.reset()
        if self.tel is not None:
            self.tel.reset()    # the stream covers this run's traffic only
        waiting = sorted(requests or [], key=lambda r: r.arrival)
        self._t0 = t0 = time.perf_counter()
        try:
            while True:
                now = time.perf_counter() - t0
                if self._draining:
                    # graceful shutdown: not-yet-due arrivals submit now and
                    # shed (typed terminal state, nothing silently dropped)
                    for r in waiting:
                        self.submit(r, now=now)
                    waiting = []
                while waiting and waiting[0].arrival <= now:
                    self.submit(waiting.pop(0), now=now)
                # a not-yet-due arrival with a free slot waiting for it caps
                # the tick horizon (an arrival into a busy pool queues
                # regardless, so it imposes no deadline)
                deadline = (waiting[0].arrival
                            if waiting and self.pool.n_free else None)
                worked = self.step(now, deadline)
                if not worked and not waiting:
                    break
                if not worked and waiting:
                    time.sleep(max(0.0, waiting[0].arrival
                                   - (time.perf_counter() - t0)))
        except KeyboardInterrupt:
            now = time.perf_counter() - t0
            self._interrupted = True
            self._draining = True
            for r in waiting:           # typed shed, not silent loss
                self.submit(r, now=now)
            waiting = []
            for st in list(self.sched.queue):
                self.sched.shed_queued(st, "interrupt", now,
                                       detail="shed: run interrupted")
            for st in (list(self.sched.prefilling)
                       + list(self.sched.decoding.values())):
                # host-only reclaim: the cache may be a donated buffer if
                # the interrupt landed mid-dispatch
                self._reclaim(st, "interrupt", now, device=False,
                              detail="interrupted with partial tokens")
            self._sync_shed_serials()
        wall = time.perf_counter() - t0
        self.sched.assert_conservation()
        if self.src_pool is not None:
            self.src_pool.assert_consistent()
            assert self.src_pool.n_used <= self.pool.n_used, \
                "source entries outlive their holders"
        if self.tel is not None:
            self.tel.flush()    # no lost JSONL tail on drain / interrupt
        return self.report(wall)

    def report(self, wall_s: float) -> dict:
        done = self.sched.retired
        gen = sum(len(s.tokens) for s in done)

        def _h(hist, q, scale=1.0):
            # streaming log-bucket percentile (one-bucket accuracy) — the
            # fixed-size replacement for the sorted-list nearest-rank _pct
            p = hist.percentile(q)
            return None if p is None else round(scale * p, 4)
        # per-slot KV memory accounting: the O(window) win of ring caches
        # (kv_rows_per_slot == ring_len << max_len) is a reported number,
        # not an inference from shapes; recurrent-state families carry no
        # KV rows and report 0. Pooled source KV (src_k / src_v) counts
        # too — with n_entries == n_slots the per-slot share is exact.
        # An int8 (+w4a8) cache counts its f32 dequant-scale planes too —
        # kv_bytes_per_slot reports the true footprint, so the ~4x win the
        # regression baseline pins is net of scale overhead.
        kv = [self.cache[k] for k in ("k", "v", "k_scale", "v_scale",
                                      "cross_k", "cross_v",
                                      "src_k", "src_v",
                                      "src_k_scale", "src_v_scale")
              if k in self.cache]
        kv_bytes = sum(int(a.size) * a.dtype.itemsize for a in kv)
        term = (self.sched.retired + self.sched.shed + self.sched.errored)
        agg = {
            "n_requests": self.sched.n_submitted,
            "n_retired": self.sched.n_retired,
            "n_rejected": len(self.sched.rejected),
            "n_shed": len(self.sched.shed),
            "n_errored": len(self.sched.errored),
            "n_deadline_missed": sum(s.code == "deadline" for s in term),
            "n_cancelled": sum(s.code == "cancelled" for s in term),
            "generated_tokens": gen,
            "wall_s": round(wall_s, 3),
            "tokens_per_s": round(gen / wall_s, 1) if wall_s else None,
            "decode_ticks": self.max_ticks,
            "decode_steps": self.decode_steps,
            "decode_dispatches": self.decode_dispatches,
            "prefill_chunks": self.prefill_chunks,
            "prefill_dispatches": self.prefill_dispatches,
            "dispatches": self.dispatches,
            "host_syncs": self.host_syncs,
            "dispatches_per_token": (round(self.dispatches / gen, 4)
                                     if gen else None),
            "issued_ticks": self.issued_ticks,
            "parked_ticks": self.parked_ticks,
            "mean_occupancy": round(
                self.active_row_steps
                / (self.decode_steps * self.pool.n_slots), 3)
                if self.decode_steps else 0.0,
            "kv_bytes_per_slot": kv_bytes // self.pool.n_slots,
            "kv_rows_per_slot": (int(self.cache["k"].shape[2])
                                 if "k" in self.cache else 0),
            "max_len": self.pool.max_len,
            "ttft_p50_s": _h(self.hist_ttft, 0.50),
            "ttft_p95_s": _h(self.hist_ttft, 0.95),
            "itl_p50_ms": _h(self.hist_itl, 0.50, scale=1e3),
            "itl_p95_ms": _h(self.hist_itl, 0.95, scale=1e3),
            "itl_source": ("subdivided" if self.max_ticks > 1 else "exact"),
            "itl_effective_ms": (round(1e3 * wall_s / gen, 4)
                                 if gen else None),
        }
        if self.tel is not None:
            agg["telemetry_events"] = len(self.tel.events)
        if self.sched.n_degraded:
            agg["n_degraded"] = self.sched.n_degraded
        if self.faults is not None:
            agg["faults_fired"] = self.faults.n_fired
            agg["faults_pending"] = self.faults.n_pending
            agg["dispatch_retries"] = self.dispatch_retries
        if self.auditor is not None:
            agg["audit_checks"] = self.auditor.n_checks
        if self._draining:
            agg["drained"] = True
        if self._interrupted:
            agg["interrupted"] = True
        if self.src_pool is not None:
            # source-KV pool accounting: ingests ran the encoder / cross
            # projections; shares were served by refcount alone (the dedup
            # win — N requests on one image pay one ingest)
            agg["source_ingests"] = self.src_pool.total_ingests
            agg["source_shares"] = self.src_pool.total_shares
            agg["src_rows_per_entry"] = self.src_pool.src_max
        if self.max_ticks > 1:
            agg["itl_note"] = (
                "decode_ticks > 1: the host syncs once per K-tick block, so "
                "per-token timestamps inside a block are attributed by even "
                "subdivision of the block's wall span (itl_source: "
                "subdivided) — itl percentiles are per-token estimates, no "
                "longer K-quantized; itl_effective_ms = wall_s / "
                "generated_tokens remains the exact denominator")
        return {
            "requests": [{
                "rid": s.rid, "prompt_len": int(len(s.request.prompt)),
                "n_tokens": len(s.tokens), "tokens": list(s.tokens),
                "ttft_s": None if s.ttft is None else round(s.ttft, 4),
                "finish_reason": s.finish_reason,
                "status": s.status, "code": s.code,
            } for s in (done + self.sched.errored + self.sched.rejected
                        + self.sched.shed)],
            "aggregate": agg,
        }
