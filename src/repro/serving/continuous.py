"""Continuous-batching serving engine: slot pool -> scheduler -> ragged
chunked prefill -> static-shape ragged decode.

The jit'd decode step always runs at ``[n_slots]`` batch shape; an ``active``
mask carries which slots hold live requests. Each engine step:

1. **admit** — backfill free slots from the admission queue;
2. **prefill** — every mid-prefill slot advances by one prompt chunk
   (``TransformerLM.prefill_chunk``), so long prompts never stall in-flight
   decodes for more than one chunk's latency; a request whose final chunk
   lands is committed (``finalize_slot``), its first token sampled from the
   chunk logits, and its slot joins the active set;
3. **decode** — one ragged ``decode_step`` over all slots; per-slot EOS /
   max-token retirement releases slots mid-flight (reset-on-release), which
   the next step's admission immediately backfills.

Greedy outputs are token-for-token identical to per-request
``ServingEngine.generate`` (tested in tests/test_serving_continuous.py):
chunked prefill reuses the same blockwise ``prefill_attention`` math,
masked-out cache rows are exact no-ops in the (mu, Z, Y) recurrence,
recurrent-state rows (ssm / hybrid) carry through masked decode steps
unchanged, and MoE rows use the capacity-free per-row dispatch so batch
composition can never perturb a request.

Sampling (temperature > 0) is fused into the jit'd decode program as
seeded per-slot Gumbel-max (``argmax(logits/T + g)`` with
``g ~ Gumbel(0,1)`` is exactly a softmax(logits/T) draw), so the device ->
host transfer is the same ``[n_slots]`` int32 on both greedy and sampled
paths — never the ``[n_slots, V]`` logits. Keys derive from
``(seed, request admission serial, token index)`` — properties of the
*request*, not of the engine's step counters — so a request's sampled
tokens are independent of batch composition and of how prefill chunks and
decode ticks interleave: a fresh engine replays a (seed, trace) pair
token-for-token even under timed Poisson arrivals.
"""
from __future__ import annotations

import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from .scheduler import Request, RequestState, Scheduler
from .slot_pool import KVSlotPool


def _pct(xs, q):
    """Nearest-rank percentile of an ascending-sorted list: element
    ceil(q*n)-1 (so p50 of [a, b] is a, and p95 only hits the max within
    5% of the tail) — truncation indexing overshoots on short lists."""
    if not xs:
        return None
    return round(float(xs[max(0, math.ceil(q * len(xs)) - 1)]), 4)


class ContinuousBatchingEngine:
    def __init__(self, model, params, *, n_slots: int, max_len: int,
                 chunk: int = 16, eos_id: int | None = None,
                 pad_id: int = 0, temperature: float = 0.0, seed: int = 0):
        if not getattr(model, "supports_ragged_serving", lambda: False)():
            raise ValueError(
                f"{model.cfg.name}: continuous batching needs a "
                "slot-serializable decode state (cross-attention source KV "
                "and ring KV caches are not poolable yet)")
        if chunk < 1 or max_len % chunk:
            raise ValueError(f"chunk ({chunk}) must divide max_len "
                             f"({max_len}) so padded chunks stay in range")
        self.model, self.params = model, params
        self.chunk, self.eos_id, self.pad_id = chunk, eos_id, pad_id
        self.temperature = temperature
        self._t0 = time.perf_counter()          # reset by run()
        self.pool = KVSlotPool(n_slots, max_len)
        self.sched = Scheduler(self.pool)
        self._prefill_chunk = jax.jit(model.prefill_chunk,
                                      donate_argnums=(2,))
        self._finalize = jax.jit(model.finalize_slot, donate_argnums=(0,))
        self._release = jax.jit(model.release_slot, donate_argnums=(0,))

        # sampler keys: (seed, request admission serial, token index) —
        # request-intrinsic, so a draw can't depend on batch composition or
        # on how the scheduler interleaved prefill chunks with decode ticks
        base_key = jax.random.PRNGKey(seed)

        def _gumbel_pick(logits, serial, token_idx):
            key = jax.random.fold_in(jax.random.fold_in(base_key, serial),
                                     token_idx)
            g = jax.random.gumbel(key, logits.shape, logits.dtype)
            return jnp.argmax(logits / temperature + g,
                              axis=-1).astype(jnp.int32)

        def _decode_pick(params, tok, cache, active, serials, emitted):
            # decode + sample in one dispatch: only [n_slots] int32 leaves
            # the device on both greedy and sampled paths
            logits, cache = model.decode_step(params, tok, cache, active)
            if temperature == 0.0:
                return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache
            return jax.vmap(_gumbel_pick)(logits, serials, emitted), cache
        self._decode_pick = jax.jit(_decode_pick, donate_argnums=(2,))

        def _prefill_pick(logits_row, serial):
            # first token off a finalized prefill: [V] -> scalar int32
            if temperature == 0.0:
                return jnp.argmax(logits_row).astype(jnp.int32)
            return _gumbel_pick(logits_row, serial, jnp.int32(0))
        self._prefill_pick = jax.jit(_prefill_pick)

        self.cache = model.init_cache(n_slots, max_len)
        self.tok = np.full((n_slots,), pad_id, np.int32)
        self.active = np.zeros((n_slots,), bool)
        # per-slot sampler state: admission serial of the occupying request
        # and how many tokens it has emitted (its next draw's token index)
        self.serial = np.zeros((n_slots,), np.int32)
        self.emitted = np.zeros((n_slots,), np.int32)
        self._serials: dict = {}        # rid -> serial, mid-prefill only
        self._serial_ctr = 0
        # counters for occupancy / utilization reporting
        self.decode_steps = 0
        self.prefill_chunks = 0
        self.active_row_steps = 0

    # ---- intake -----------------------------------------------------------
    def submit(self, request: Request, now: float = 0.0) -> RequestState:
        state = self.sched.submit(request, now)
        if state.status != "rejected":
            # admission order is FIFO over submission order, so the serial
            # is a deterministic property of the trace
            self._serials[state.rid] = self._serial_ctr
            self._serial_ctr += 1
        return state

    def warmup(self) -> "ContinuousBatchingEngine":
        """Compile the chunk / finalize / decode / release programs with a
        throwaway multi-chunk request. ``run`` drops finished-traffic stats
        at entry so reports cover real traffic only; the warmup request
        consumes exactly one sampler serial, so two warmed-up engines with
        the same seed still draw identical streams."""
        p = max(1, min(self.chunk + 1, self.pool.capacity - 2))
        self.run([Request(prompt=np.zeros(p, np.int32), max_new_tokens=2,
                          rid="__warmup__")])
        return self

    # ---- one engine step --------------------------------------------------
    def step(self, now: float | None = None) -> bool:
        """Admit + advance every prefilling slot one chunk + one ragged
        decode step. Returns False when nothing was left to do."""
        now = (time.perf_counter() - self._t0) if now is None else now
        self.sched.admit(now)

        for state in list(self.sched.prefilling):
            self._advance_prefill(state)

        if not self.active.any():
            return self.sched.pending()

        tok, act = jnp.asarray(self.tok), jnp.asarray(self.active)
        picks, self.cache = self._decode_pick(
            self.params, tok, self.cache, act,
            jnp.asarray(self.serial), jnp.asarray(self.emitted))
        rows = np.asarray(picks)
        self.decode_steps += 1
        self.active_row_steps += int(self.active.sum())
        for slot in np.flatnonzero(self.active):
            state = self.sched.decoding[int(slot)]
            self.pool.advance(int(slot))
            self._emit(state, int(rows[slot]))
        return True

    def _advance_prefill(self, state: RequestState) -> None:
        prompt = state.request.prompt
        off = state.prefilled
        toks = prompt[off:off + self.chunk]
        if toks.size < self.chunk:
            toks = np.pad(toks, (0, self.chunk - toks.size),
                          constant_values=self.pad_id)
        last = min(self.chunk - 1, max(0, len(prompt) - 1 - off))
        logits, self.cache = self._prefill_chunk(
            self.params, jnp.asarray(toks), self.cache,
            jnp.int32(state.slot), jnp.int32(off), jnp.int32(last))
        self.prefill_chunks += 1
        state.prefilled = min(off + self.chunk, len(prompt))
        if state.prefilled < len(prompt):
            return    # non-final chunk: logits row never fetched from device
        # final chunk: commit the slot, sample the first token on device
        # (a scalar int32 transfer, not the [V] logits row)
        self.cache = self._finalize(self.cache, jnp.int32(state.slot),
                                    len(prompt))
        self.sched.start_decoding(state)
        self.serial[state.slot] = self._serials.pop(state.rid)
        self._emit(state, int(self._prefill_pick(
            logits, jnp.int32(self.serial[state.slot]))))

    def _emit(self, state: RequestState, token: int) -> None:
        # stamped here, after np.asarray blocked on the device work that
        # produced the token — a step-entry clock would understate TTFT/ITL
        # by up to one whole engine step
        now = time.perf_counter() - self._t0
        state.tokens.append(token)
        state.token_times.append(now)
        if state.t_first is None:
            state.t_first = now
        done = (self.eos_id is not None and token == self.eos_id)
        if done or len(state.tokens) >= state.request.max_new_tokens:
            reason = "eos" if done else "max_tokens"
            slot = self.sched.retire(state, reason, now)
            self.cache = self._release(self.cache, jnp.int32(slot))
            self.active[slot] = False
            self.tok[slot] = self.pad_id
        else:
            self.active[state.slot] = True
            self.tok[state.slot] = token
            self.emitted[state.slot] = len(state.tokens)

    # ---- drive a whole trace ----------------------------------------------
    def run(self, requests: list[Request] | None = None) -> dict:
        """Drive until every request retires. Each request is submitted once
        the wall clock passes its ``Request.arrival`` offset (0.0 on every
        request = a fully backlogged throughput run); when the engine is
        idle it sleeps until the next arrival, so TTFT measures from the
        request's actual submission."""
        # per-run stats: an engine is reusable (warmup, successive traces),
        # so drop finished-traffic history before timing starts
        self.sched.reset_stats()
        self.pool.reset_stats()
        self.decode_steps = self.prefill_chunks = self.active_row_steps = 0
        waiting = sorted(requests or [], key=lambda r: r.arrival)
        self._t0 = t0 = time.perf_counter()
        while True:
            now = time.perf_counter() - t0
            while waiting and waiting[0].arrival <= now:
                self.submit(waiting.pop(0), now=now)
            worked = self.step(now)
            if not worked and not waiting:
                break
            if not worked and waiting:
                time.sleep(max(0.0, waiting[0].arrival
                               - (time.perf_counter() - t0)))
        wall = time.perf_counter() - t0
        self.sched.assert_conservation()
        return self.report(wall)

    def report(self, wall_s: float) -> dict:
        done = self.sched.retired
        gen = sum(len(s.tokens) for s in done)
        ttfts = sorted(s.ttft for s in done if s.ttft is not None)
        itls = sorted(x for s in done for x in s.itl_ms)
        return {
            "requests": [{
                "rid": s.rid, "prompt_len": int(len(s.request.prompt)),
                "n_tokens": len(s.tokens), "tokens": list(s.tokens),
                "ttft_s": None if s.ttft is None else round(s.ttft, 4),
                "finish_reason": s.finish_reason,
            } for s in done + self.sched.rejected],
            "aggregate": {
                "n_requests": self.sched.n_submitted,
                "n_retired": self.sched.n_retired,
                "n_rejected": len(self.sched.rejected),
                "generated_tokens": gen,
                "wall_s": round(wall_s, 3),
                "tokens_per_s": round(gen / wall_s, 1) if wall_s else None,
                "decode_steps": self.decode_steps,
                "prefill_chunks": self.prefill_chunks,
                "mean_occupancy": round(
                    self.active_row_steps
                    / (self.decode_steps * self.pool.n_slots), 3)
                    if self.decode_steps else 0.0,
                "ttft_p50_s": _pct(ttfts, 0.50),
                "ttft_p95_s": _pct(ttfts, 0.95),
                "itl_p50_ms": _pct(itls, 0.50),
                "itl_p95_ms": _pct(itls, 0.95),
            },
        }
