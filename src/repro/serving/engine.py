"""Batched serving engine: prefill once, then per-token decode steps — the
paper's workload. The decode step is the jit'd unit the dry-run lowers
(``serve_step``); the KV cache is donated so steps update in place.

Batching model: requests of equal prompt length are grouped (uniform-length
prefill; DESIGN.md notes), per-row ``len`` diverges during generation when
requests complete early (an ``active`` mask freezes finished rows)."""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp


class ServingEngine:
    def __init__(self, model, params, *, max_len: int, batch: int,
                 source_len: int | None = None):
        if getattr(model.cfg, "w4a8_serve", False):
            # +w4a8 config: one-shot weight quantization at engine
            # construction (deterministic — no RNG — so seeded-sampling
            # replay invariance is preserved bit-for-bit); the KV side is
            # handled by init_cache's int8 default for these configs
            from repro.models.quantized import quantize_params
            params = quantize_params(params)
        self.model, self.params = model, params
        self.max_len, self.batch = max_len, batch
        self.source_len = source_len
        self._prefill = jax.jit(model.prefill)
        self._decode = jax.jit(model.decode_step, donate_argnums=(2,))

    def new_cache(self):
        return self.model.init_cache(self.batch, self.max_len, self.source_len)

    def generate(self, prompts: jax.Array, *, steps: int,
                 temperature: float = 0.0, rng=None,
                 eos_id: int | None = None, pad_id: int = 0,
                 source: jax.Array | None = None,
                 source_len: jax.Array | None = None) -> jax.Array:
        """prompts: [B, P] int32 (uniform length). Returns [B, steps].

        ``source``: [B, S_src, d] cross-attention features, padded to a
        uniform S_src; ``source_len``: optional [B] true lengths — prefill
        masks each row's padded source tail and the decode cross reads
        inherit the mask through ``cache['source_len']``, so rows with
        heterogeneous encoder lengths batch together.

        A row that emits ``eos_id`` is retired: the EOS token itself is
        emitted, every later step emits ``pad_id``, and the row's decode
        output is frozen (the lock-step batch keeps its static shape, so
        retired rows still ride through the decode step — their slots are
        *reclaimable*, which is what the continuous-batching engine
        (``repro.serving.continuous``) exploits by backfilling them from its
        admission queue). Pick a ``pad_id`` outside the live vocab when the
        output is parsed downstream."""
        b, p = prompts.shape
        assert b == self.batch and p + steps <= self.max_len
        rng = jax.random.PRNGKey(0) if rng is None else rng
        cache = self.new_cache()
        logits, cache = self._prefill(self.params, prompts, cache, source,
                                      source_len)
        outs = []
        active = jnp.ones((b,), bool)
        tok = self._sample(logits, temperature, rng)
        for t in range(steps):
            outs.append(jnp.where(active, tok, pad_id))
            if eos_id is not None:
                active &= tok != eos_id
            rng, sub = jax.random.split(rng)
            logits, cache = self._decode(self.params, tok, cache)
            tok = jnp.where(active, self._sample(logits, temperature, sub), tok)
        return jnp.stack(outs, axis=1)

    @staticmethod
    def _sample(logits: jax.Array, temperature: float, rng) -> jax.Array:
        if temperature == 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return jax.random.categorical(rng, logits / temperature).astype(jnp.int32)
