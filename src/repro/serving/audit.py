"""Engine invariant auditor: cross-layer consistency checks for the
continuous batching engine, run after every decode block (or every
``every``-th) when enabled — and costing *nothing* when not, exactly like
telemetry: the engine holds ``auditor=None`` by default and the single call
site is guarded, so the disabled path is the unchanged host loop.

The scheduler, the slot pool, the source pool, and the engine's device-
mirrored arrays (``active`` / ``tok`` / ``budget`` / ``emitted``) each keep
their own view of "who is running"; a robustness bug (leaked slot, stale
active bit, refcount drift, ledger length skew) shows up as those views
disagreeing long before it corrupts tokens. :class:`EngineAuditor.check`
asserts the full cross-ledger contract:

* **free-list consistency** — ``KVSlotPool.assert_consistent`` (no slot
  both free and owned, alloc/release conservation, freed slots at length
  0), plus slot-owner agreement: the pool's ``slot -> owner`` map names
  exactly the scheduler's prefilling + decoding rids.
* **source-pool refcount conservation** — ``SourceKVPool.assert_consistent``
  plus ``total_refs() == len(engine._srcs)`` (every live reference is held
  by exactly one in-flight request) and ``n_used <= pool.n_used`` (entries
  never outlive their holders).
* **active-mask / parked-write contract** — ``active``'s true rows are
  exactly the scheduler's decoding slots; an active row's ``budget`` is its
  request's ``max_new_tokens``, its ``emitted`` its token count, its ``tok``
  its last token; a *free* slot's ``tok`` is ``pad_id`` and ``budget`` 0,
  so a stale row could never decode as live.
* **KV length ledger** — a decoding slot's pool length equals
  ``prompt_len + tokens - 1`` (the first token is sampled off prefill
  logits and writes no KV row; every later token advanced the ledger), and
  a prefilling slot's equals its committed chunk prefix.
* **request conservation** — ``Scheduler.assert_conservation`` (every
  submitted request in exactly one terminal/live bucket, typed codes on
  every terminal record, admitted == decoding + prefilling + retired +
  errored).

Violations raise :class:`AuditViolation` immediately (subclass of
``AssertionError``: a failed audit is a bug in the engine, not an operating
condition), carrying the failed invariant's name.
"""
from __future__ import annotations

import numpy as np


class AuditViolation(AssertionError):
    """An engine invariant does not hold. ``invariant`` names the check."""

    def __init__(self, invariant: str, detail: str):
        super().__init__(f"[{invariant}] {detail}")
        self.invariant = invariant


class EngineAuditor:
    """``every``: audit each ``every``-th decode block (1 = every block).
    ``n_checks`` counts completed full audits — a chaos run asserting
    recovery must also assert this is > 0, or the audit never ran."""

    def __init__(self, every: int = 1):
        if every < 1:
            raise ValueError(f"every must be >= 1, got {every}")
        self.every = every
        self.n_checks = 0
        self._calls = 0

    def reset(self) -> None:
        """Zero the counters (the engine calls this at each ``run()`` entry
        so ``audit_checks`` in the report covers that run only)."""
        self.n_checks = 0
        self._calls = 0

    def maybe_check(self, engine) -> bool:
        """Rate-limited entry point the engine calls per block."""
        self._calls += 1
        if self._calls % self.every:
            return False
        self.check(engine)
        return True

    def check(self, engine) -> None:
        self._pools(engine)
        self._active_contract(engine)
        self._length_ledger(engine)
        try:
            engine.sched.assert_conservation()
        except AssertionError as e:
            raise AuditViolation("request_conservation", str(e)) from e
        self.n_checks += 1

    # ---- individual invariant groups --------------------------------------
    def _pools(self, engine) -> None:
        try:
            engine.pool.assert_consistent()
        except AssertionError as e:
            raise AuditViolation("free_list", str(e)) from e
        sched = engine.sched
        holders = {st.slot: st.rid for st in sched.prefilling}
        holders.update({slot: st.rid for slot, st in sched.decoding.items()})
        owners = engine.pool.used_slots()
        if owners != holders:
            raise AuditViolation(
                "slot_owners",
                f"pool owners {owners} != scheduler holders {holders}")
        if engine.src_pool is not None:
            try:
                engine.src_pool.assert_consistent()
            except AssertionError as e:
                raise AuditViolation("source_pool", str(e)) from e
            refs, held = engine.src_pool.total_refs(), len(engine._srcs)
            if refs != held:
                raise AuditViolation(
                    "source_refcounts",
                    f"{refs} live references vs {held} holding requests")
            if engine.src_pool.n_used > engine.pool.n_used:
                raise AuditViolation(
                    "source_refcounts",
                    f"{engine.src_pool.n_used} source entries in use with "
                    f"only {engine.pool.n_used} slots held")

    def _active_contract(self, engine) -> None:
        sched = engine.sched
        active = set(int(s) for s in np.flatnonzero(engine.active))
        decoding = set(sched.decoding)
        if active != decoding:
            raise AuditViolation(
                "active_mask",
                f"active rows {sorted(active)} != decoding slots "
                f"{sorted(decoding)}")
        for slot, st in sched.decoding.items():
            want = st.request.max_new_tokens
            if int(engine.budget[slot]) != want:
                raise AuditViolation(
                    "active_mask",
                    f"slot {slot} ({st.rid!r}): budget "
                    f"{int(engine.budget[slot])} != max_new_tokens {want}")
            if int(engine.emitted[slot]) != len(st.tokens):
                raise AuditViolation(
                    "active_mask",
                    f"slot {slot} ({st.rid!r}): emitted "
                    f"{int(engine.emitted[slot])} != {len(st.tokens)} tokens")
            if st.tokens and int(engine.tok[slot]) != st.tokens[-1]:
                raise AuditViolation(
                    "active_mask",
                    f"slot {slot} ({st.rid!r}): tok {int(engine.tok[slot])} "
                    f"!= last token {st.tokens[-1]}")
        held = decoding | {st.slot for st in sched.prefilling}
        for slot in range(engine.pool.n_slots):
            if slot in held:
                continue
            if int(engine.tok[slot]) != engine.pad_id:
                raise AuditViolation(
                    "parked_write",
                    f"free slot {slot} keeps tok {int(engine.tok[slot])} "
                    f"(pad_id {engine.pad_id})")
            if int(engine.budget[slot]) != 0:
                raise AuditViolation(
                    "parked_write",
                    f"free slot {slot} keeps budget "
                    f"{int(engine.budget[slot])}")

    def _length_ledger(self, engine) -> None:
        for slot, st in engine.sched.decoding.items():
            want = len(st.request.prompt) + max(0, len(st.tokens) - 1)
            got = engine.pool.length(slot)
            if got != want:
                raise AuditViolation(
                    "length_ledger",
                    f"slot {slot} ({st.rid!r}): ledger length {got} != "
                    f"prompt {len(st.request.prompt)} + "
                    f"{len(st.tokens)} tokens - 1 = {want}")
        for st in engine.sched.prefilling:
            got = engine.pool.length(st.slot)
            if got != 0:
                # set_length happens at start_decoding; mid-prefill slots
                # stay at 0 (chunk progress lives in state.prefilled)
                raise AuditViolation(
                    "length_ledger",
                    f"prefilling slot {st.slot} ({st.rid!r}) has ledger "
                    f"length {got} before start_decoding")
