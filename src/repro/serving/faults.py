"""Deterministic fault injection for the continuous serving engine.

A :class:`FaultPlan` is a seeded, replayable list of :class:`Fault`\\ s the
engine consults at its four failure seams:

======================  =====================================================
kind                    injected where / recovery contract
======================  =====================================================
``poison_nan``          the victim request's logits row is overwritten with
                        NaN inside the decode block (``decode_multi``'s
                        ``poison`` mask). The on-device finite check turns
                        the row into the ``-2`` quarantine sentinel on the
                        existing ``[K, n_slots]`` sync; the engine retires
                        *only* that request as ERRORED
                        (``nonfinite_logits``), reclaims its slot + source
                        reference, and every other stream stays
                        byte-identical.
``ingest_fail``         the victim's source-KV ingest fails at admission:
                        the request is retired as ERRORED
                        (``source_ingest_failed``) before any device write,
                        its slot returned to the free list the same step.
``dispatch_fail``       a decode-block dispatch raises *before* the jit
                        call (so the donated cache was never consumed and
                        the retry re-dispatches safely); the engine counts
                        the retry and proceeds — tokens are unaffected.
``tick_delay``          the engine sleeps ``delay_s`` before a decode
                        dispatch — a stall, not an error; exercises the
                        timing-robustness of deadline bookkeeping.
======================  =====================================================

Determinism: a plan is pure data — no clocks, no global RNG. ``poison_nan``
and ``ingest_fail`` target a request id and (for poison) an emitted-token
threshold, both properties of the *request*, not of wall time, so the same
plan over the same trace fires at the same request-relative point on every
run; :meth:`FaultPlan.replay` returns a fresh unfired copy for exact-replay
assertions. :meth:`FaultPlan.random` derives a plan from a seed via
``numpy``'s deterministic generator.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace

import numpy as np

FAULT_KINDS = ("poison_nan", "ingest_fail", "dispatch_fail", "tick_delay")


class FaultInjected(RuntimeError):
    """Raised at a seam when a matching fault fires (``dispatch_fail``
    raises it for real so the engine's retry path is a genuine
    try/except)."""

    def __init__(self, fault: "Fault"):
        super().__init__(f"injected fault: {fault.kind} "
                         f"(rid={fault.rid!r}, block>={fault.block})")
        self.fault = fault


@dataclass(eq=False)
class Fault:
    """One injected failure. ``rid`` targets a request (``poison_nan`` /
    ``ingest_fail``); ``block`` is the earliest decode-dispatch index the
    fault may fire at (engine-global counter); ``after_tokens`` gates
    ``poison_nan`` on the victim having emitted at least that many tokens
    (>= 1 is always true once decoding — the prefill first token — so the
    default fires at the victim's first decode block, making the fired
    point a request-relative, replay-deterministic event even under timed
    arrivals); ``delay_s`` is the ``tick_delay`` stall."""
    kind: str
    rid: object = None
    block: int = 0
    after_tokens: int = 1
    delay_s: float = 0.0
    fired: bool = field(default=False, compare=False)
    fired_block: int | None = field(default=None, compare=False)

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(known: {FAULT_KINDS})")
        if self.kind in ("poison_nan", "ingest_fail") and self.rid is None:
            raise ValueError(f"{self.kind} requires a target rid")
        if self.block < 0 or self.after_tokens < 0 or self.delay_s < 0:
            raise ValueError("block / after_tokens / delay_s must be >= 0")

    def to_json(self) -> dict:
        out = {"kind": self.kind, "block": self.block, "fired": self.fired}
        if self.rid is not None:
            out["rid"] = self.rid
        if self.kind == "poison_nan":
            out["after_tokens"] = self.after_tokens
        if self.kind == "tick_delay":
            out["delay_s"] = self.delay_s
        if self.fired_block is not None:
            out["fired_block"] = self.fired_block
        return out


class FaultPlan:
    """An ordered set of faults plus fired-state bookkeeping. Engines call
    the ``take_*`` methods at their seams; each fault fires at most once."""

    def __init__(self, faults: list[Fault], seed: int | None = None):
        self.faults = list(faults)
        self.seed = seed

    # ---- construction ------------------------------------------------------
    @classmethod
    def random(cls, seed: int, rids: list, *, n_faults: int = 3,
               kinds: tuple = ("poison_nan", "dispatch_fail", "tick_delay"),
               max_block: int = 3) -> "FaultPlan":
        """Deterministic plan from a seed: ``n_faults`` draws of kind /
        victim / firing block. ``ingest_fail`` must be opted into via
        ``kinds`` (it only makes sense on source-bearing configs). Distinct
        victims per targeted fault, so expected-errored sets are exact."""
        if not rids:
            raise ValueError("need at least one candidate rid")
        rng = np.random.default_rng(seed)
        pool = list(rids)
        faults = []
        for _ in range(n_faults):
            kind = str(rng.choice(kinds))
            if kind in ("poison_nan", "ingest_fail"):
                if not pool:
                    kind = "dispatch_fail"   # victims exhausted: benign kind
                else:
                    victim = pool.pop(int(rng.integers(len(pool))))
                    faults.append(Fault(kind, rid=victim,
                                        block=int(rng.integers(max_block + 1))
                                        if kind == "poison_nan" else 0))
                    continue
            if kind == "tick_delay":
                faults.append(Fault(kind,
                                    block=int(rng.integers(max_block + 1)),
                                    delay_s=float(rng.uniform(5e-4, 2e-3))))
            else:
                faults.append(Fault(kind,
                                    block=int(rng.integers(max_block + 1))))
        return cls(faults, seed=seed)

    def replay(self) -> "FaultPlan":
        """A fresh, unfired copy of the same plan — run it over the same
        trace and every fault fires at the same request-relative point."""
        return FaultPlan([replace(f, fired=False, fired_block=None)
                          for f in self.faults], seed=self.seed)

    # ---- seam queries (each fault fires at most once) ----------------------
    def take_ingest(self, rid) -> Fault | None:
        """First unfired ``ingest_fail`` targeting ``rid``, marked fired."""
        for f in self.faults:
            if f.kind == "ingest_fail" and not f.fired and f.rid == rid:
                f.fired = True
                return f
        return None

    def take_poison(self, candidates: dict, block: int) -> list:
        """``candidates``: ``{rid: emitted_tokens}`` for the rows decoding
        in the block about to dispatch. Returns the rids to NaN-poison this
        block (matching unfired faults marked fired)."""
        hit = []
        for f in self.faults:
            if (f.kind == "poison_nan" and not f.fired
                    and f.rid in candidates and block >= f.block
                    and candidates[f.rid] >= f.after_tokens):
                f.fired = True
                f.fired_block = block
                hit.append(f.rid)
        return hit

    def take(self, kind: str, *, block: int) -> Fault | None:
        """First unfired untargeted fault of ``kind`` whose firing block
        has been reached, marked fired (``dispatch_fail`` /
        ``tick_delay``)."""
        for f in self.faults:
            if f.kind == kind and not f.fired and block >= f.block:
                f.fired = True
                f.fired_block = block
                return f
        return None

    def raise_if(self, kind: str, *, block: int) -> None:
        """Raise :class:`FaultInjected` when a matching fault fires — the
        ``dispatch_fail`` seam, called *before* the jit dispatch so the
        donated cache is untouched and the engine's retry is safe."""
        f = self.take(kind, block=block)
        if f is not None:
            raise FaultInjected(f)

    # ---- queries -----------------------------------------------------------
    @property
    def n_fired(self) -> int:
        return sum(f.fired for f in self.faults)

    @property
    def n_pending(self) -> int:
        return sum(not f.fired for f in self.faults)

    def fired(self, kind: str | None = None) -> list[Fault]:
        return [f for f in self.faults
                if f.fired and (kind is None or f.kind == kind)]

    def victims(self) -> list:
        """rids of fired *targeted* faults — the exact set of requests a
        clean recovery must (and must only) retire as errored."""
        return [f.rid for f in self.faults
                if f.fired and f.kind in ("poison_nan", "ingest_fail")]

    def to_json(self) -> dict:
        return {"seed": self.seed,
                "faults": [f.to_json() for f in self.faults]}

    def __len__(self) -> int:
        return len(self.faults)

    def __repr__(self) -> str:
        return (f"FaultPlan(seed={self.seed}, n={len(self.faults)}, "
                f"fired={self.n_fired})")
