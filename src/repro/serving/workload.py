"""Load harness: Poisson / trace-driven request generation for the serving
benchmarks. Produces plain :class:`repro.serving.scheduler.Request` lists so
the same trace drives both the continuous engine and the lock-step baseline.
"""
from __future__ import annotations

import json
from pathlib import Path

import numpy as np

from .scheduler import Request

TRACE_SHAPES = ("poisson", "bursty", "heavy-tail")


def _arrivals(rng: np.random.Generator, n: int, rate: float | None,
              shape: str, burst: int, tail_alpha: float) -> np.ndarray:
    """Arrival-time vector for ``n`` requests at mean ``rate`` req/s.

    ``poisson`` is the well-behaved baseline (i.i.d. exponential gaps —
    the exact draw order the pre-shape trace generator used, so existing
    seeded traces replay unchanged). ``bursty`` models synchronized client
    behavior: bursts of ``burst`` requests arrive nearly back-to-back
    (intra-burst gaps ~20x tighter than the mean), with burst *starts*
    Poisson at ``rate / burst`` so the long-run rate still averages
    ``rate`` — the queue sees deep instantaneous overload even when the
    mean load is feasible. ``heavy-tail`` draws Lomax (Pareto-II) gaps
    with shape ``tail_alpha`` scaled to the same mean: most gaps are tiny
    (clumps) but occasional huge gaps drain the queue — the
    high-variance regime where admission control earns its keep."""
    if rate is None:
        return np.zeros(n)
    if shape == "poisson":
        return np.cumsum(rng.exponential(1.0 / rate, n))
    if shape == "bursty":
        n_bursts = -(-n // burst)
        starts = np.cumsum(rng.exponential(burst / rate, n_bursts))
        gaps = rng.exponential(1.0 / (rate * 20.0), n)
        out = np.empty(n)
        for b in range(n_bursts):
            lo, hi = b * burst, min(n, (b + 1) * burst)
            out[lo:hi] = starts[b] + np.cumsum(gaps[lo:hi])
        return out
    if shape == "heavy-tail":
        if tail_alpha <= 1.0:
            raise ValueError("tail_alpha must be > 1 (finite-mean Lomax)")
        scale = (tail_alpha - 1.0) / rate        # Lomax mean = scale/(a-1)
        return np.cumsum(rng.pareto(tail_alpha, n) * scale)
    raise ValueError(f"unknown trace shape {shape!r} "
                     f"(known: {TRACE_SHAPES})")


def poisson_trace(*, n_requests: int, vocab_size: int,
                  rate: float | None = None,
                  prompt_len: tuple[int, int] = (8, 48),
                  max_new: tuple[int, int] = (4, 128),
                  seed: int = 0,
                  source_len: tuple[int, int] | None = None,
                  source_dim: int = 0,
                  source_share: int = 0,
                  shape: str = "poisson",
                  burst: int = 8,
                  tail_alpha: float = 1.5) -> list[Request]:
    """Ragged trace: prompt lengths and output budgets drawn uniformly from
    their ranges (mixed-length — the shape production traffic actually has),
    arrivals at mean ``rate`` req/s (``None``: all backlogged at t=0) with
    the interarrival ``shape`` of :func:`_arrivals` — ``"poisson"``
    (default, the historical behavior, bit-identical draws for a given
    seed), ``"bursty"`` (``burst``-sized near-simultaneous clumps), or
    ``"heavy-tail"`` (Lomax gaps, ``tail_alpha``) for overload testing.

    ``source_len`` + ``source_dim`` attach a cross-attention source to every
    request: ``[L, source_dim]`` float32 features with L drawn uniformly
    from the range — *heterogeneous* encoder lengths, the shape mixed
    vision/audio traffic has. ``source_share`` > 1 reuses each generated
    source (and its ``source_id``) across that many consecutive requests —
    e.g. N questions about one image — exercising the source-KV pool's
    refcounted dedup."""
    rng = np.random.default_rng(seed)
    arrivals = _arrivals(rng, n_requests, rate, shape, burst, tail_alpha)
    reqs = []
    src, sid = None, None
    for i in range(n_requests):
        p = int(rng.integers(prompt_len[0], prompt_len[1], endpoint=True))
        if source_len is not None and source_dim:
            if src is None or source_share < 2 or i % source_share == 0:
                ln = int(rng.integers(source_len[0], source_len[1],
                                      endpoint=True))
                src = (rng.standard_normal((ln, source_dim))
                       .astype(np.float32) * 0.02)
                sid = f"src-{i}" if source_share > 1 else None
        reqs.append(Request(
            prompt=rng.integers(0, vocab_size, p).astype(np.int32),
            max_new_tokens=int(rng.integers(max_new[0], max_new[1],
                                            endpoint=True)),
            rid=i, arrival=float(arrivals[i]), source=src, source_id=sid))
    return reqs


def load_trace(path: str | Path, vocab_size: int) -> list[Request]:
    """Trace file: JSON list of {"prompt_len" | "prompt", "max_new_tokens",
    "arrival"?} records. ``prompt_len`` entries get deterministic synthetic
    token ids (seeded per record) clipped to the vocab."""
    records = json.loads(Path(path).read_text())
    reqs = []
    for i, rec in enumerate(records):
        if "prompt" in rec:
            prompt = np.asarray(rec["prompt"], np.int32) % vocab_size
        else:
            rng = np.random.default_rng(rec.get("seed", i))
            prompt = rng.integers(0, vocab_size,
                                  int(rec["prompt_len"])).astype(np.int32)
        reqs.append(Request(prompt=prompt,
                            max_new_tokens=int(rec["max_new_tokens"]),
                            rid=rec.get("rid", i),
                            arrival=float(rec.get("arrival", 0.0))))
    return reqs
