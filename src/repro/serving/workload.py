"""Load harness: Poisson / trace-driven request generation for the serving
benchmarks. Produces plain :class:`repro.serving.scheduler.Request` lists so
the same trace drives both the continuous engine and the lock-step baseline.
"""
from __future__ import annotations

import json
from pathlib import Path

import numpy as np

from .scheduler import Request


def poisson_trace(*, n_requests: int, vocab_size: int,
                  rate: float | None = None,
                  prompt_len: tuple[int, int] = (8, 48),
                  max_new: tuple[int, int] = (4, 128),
                  seed: int = 0,
                  source_len: tuple[int, int] | None = None,
                  source_dim: int = 0,
                  source_share: int = 0) -> list[Request]:
    """Ragged trace: prompt lengths and output budgets drawn uniformly from
    their ranges (mixed-length — the shape production traffic actually has),
    arrivals Poisson at ``rate`` req/s (``None``: all backlogged at t=0).

    ``source_len`` + ``source_dim`` attach a cross-attention source to every
    request: ``[L, source_dim]`` float32 features with L drawn uniformly
    from the range — *heterogeneous* encoder lengths, the shape mixed
    vision/audio traffic has. ``source_share`` > 1 reuses each generated
    source (and its ``source_id``) across that many consecutive requests —
    e.g. N questions about one image — exercising the source-KV pool's
    refcounted dedup."""
    rng = np.random.default_rng(seed)
    arrivals = (np.zeros(n_requests) if rate is None
                else np.cumsum(rng.exponential(1.0 / rate, n_requests)))
    reqs = []
    src, sid = None, None
    for i in range(n_requests):
        p = int(rng.integers(prompt_len[0], prompt_len[1], endpoint=True))
        if source_len is not None and source_dim:
            if src is None or source_share < 2 or i % source_share == 0:
                ln = int(rng.integers(source_len[0], source_len[1],
                                      endpoint=True))
                src = (rng.standard_normal((ln, source_dim))
                       .astype(np.float32) * 0.02)
                sid = f"src-{i}" if source_share > 1 else None
        reqs.append(Request(
            prompt=rng.integers(0, vocab_size, p).astype(np.int32),
            max_new_tokens=int(rng.integers(max_new[0], max_new[1],
                                            endpoint=True)),
            rid=i, arrival=float(arrivals[i]), source=src, source_id=sid))
    return reqs


def load_trace(path: str | Path, vocab_size: int) -> list[Request]:
    """Trace file: JSON list of {"prompt_len" | "prompt", "max_new_tokens",
    "arrival"?} records. ``prompt_len`` entries get deterministic synthetic
    token ids (seeded per record) clipped to the vocab."""
    records = json.loads(Path(path).read_text())
    reqs = []
    for i, rec in enumerate(records):
        if "prompt" in rec:
            prompt = np.asarray(rec["prompt"], np.int32) % vocab_size
        else:
            rng = np.random.default_rng(rec.get("seed", i))
            prompt = rng.integers(0, vocab_size,
                                  int(rec["prompt_len"])).astype(np.int32)
        reqs.append(Request(prompt=prompt,
                            max_new_tokens=int(rec["max_new_tokens"]),
                            rid=rec.get("rid", i),
                            arrival=float(rec.get("arrival", 0.0))))
    return reqs
