"""Continuous-batching request scheduler + overload admission control.

Lifecycle of a request::

    submit -> QUEUED -> (slot alloc) PREFILLING -> DECODING -> RETIRED
                 |            \\------------- abort ---------> RETIRED
                 |             \\------------ abort ---------> ERRORED
                 |\\-> REJECTED (infeasible: prompt/source can never fit)
                 \\--> SHED     (overload control dropped it: queue full,
                                drain, unattainable TTFT deadline)

Terminal taxonomy (every terminal state carries a machine-readable
``RequestState.code`` next to the human ``finish_reason`` string):

* **rejected** — the request could *never* be served (``prompt_too_long``,
  ``budget_too_large``, ``source_too_long``, ``source_id_without_source``);
* **shed** — the request was feasible but overload control dropped it
  before it held a slot (``queue_full``, ``ttft_unattainable``,
  ``deadline``, ``cancelled``, ``drain``);
* **retired** — the request held a slot and ended: normally (``eos`` /
  ``max_tokens``) or stopped mid-flight (``deadline``, ``cancelled``,
  ``drain``) with its partial tokens preserved;
* **errored** — the request held a slot and was quarantined with a typed
  error (``nonfinite_logits``, ``source_ingest_failed``); its slot and
  source reference were reclaimed, every other stream untouched.

The scheduler owns the host-side bookkeeping only: the FIFO admission queue
(optionally **bounded** — see :class:`OverloadConfig`), slot assignment from
the :class:`KVSlotPool`, per-request token ledgers and timing, and
retirement (EOS / max-token) with prompt backfill — a freed slot is handed
to the next queued request at the following engine step's admission, so it
never idles while work is waiting. All device work (chunked prefill, ragged
decode, cache resets, source-KV ingest for cross-attention requests) lives
in :mod:`repro.serving.continuous`; the engine may also veto a request at
submit time with a precomputed ``reject`` (infeasible) or ``shed``
(overload) reason, which flows through the same terminal bookkeeping.

Conservation invariant (checked by ``assert_conservation``): every submitted
request is in exactly one of queued / prefilling / decoding / retired /
rejected / shed / errored, every admitted request reaches exactly one of
retired / errored, and no slot leaks.
"""
from __future__ import annotations

import itertools
from collections import deque
from dataclasses import dataclass, field
from typing import Iterable

import numpy as np

from .slot_pool import KVSlotPool

QUEUED, PREFILLING, DECODING, RETIRED, REJECTED, SHED, ERRORED = (
    "queued", "prefilling", "decoding", "retired", "rejected", "shed",
    "errored")

SHED_POLICIES = ("reject", "shed-oldest", "degrade")


@dataclass(frozen=True)
class OverloadConfig:
    """Bounded-admission-queue policy for the continuous engine.

    ``max_queue`` bounds the FIFO depth; what happens on overflow is the
    ``policy``:

    * ``"reject"``   — shed the *incoming* request (code ``queue_full``);
      the queue holds a hard depth bound and earlier arrivals keep their
      positions (favors requests already waiting).
    * ``"shed-oldest"`` — shed the *oldest queued* request and enqueue the
      incoming one (favors fresh arrivals: the oldest has burned the most
      of its latency budget and is the least likely to meet any SLO).
      Also a hard depth bound.
    * ``"degrade"``  — keep everyone, but on each overflow multiply the
      ``max_new_tokens`` of every queued request (and the incoming one) by
      ``degrade_factor`` (floored at 1 token). Bounds queued *work*, not
      queue length — the depth may exceed ``max_queue``.

    Shed requests terminate with status ``"shed"`` (never an exception):
    overload is an expected operating regime, not an error."""
    max_queue: int = 64
    policy: str = "reject"
    degrade_factor: float = 0.5

    def __post_init__(self):
        if self.max_queue < 1:
            raise ValueError("max_queue must be >= 1")
        if self.policy not in SHED_POLICIES:
            raise ValueError(f"policy must be one of {SHED_POLICIES}, "
                             f"got {self.policy!r}")
        if not (0.0 < self.degrade_factor < 1.0):
            raise ValueError("degrade_factor must be in (0, 1)")


def _reason(value, default_code: str) -> tuple[str, str]:
    """Normalize an engine-supplied reject/shed reason: either a plain
    human-readable string (legacy callers; coded with ``default_code``) or
    a ``(code, detail)`` pair."""
    if isinstance(value, tuple):
        code, detail = value
        return str(code), str(detail)
    return default_code, str(value)


@dataclass(eq=False)               # identity equality: prompts are arrays
class Request:
    """One generation request. ``arrival`` is seconds on the engine clock
    (0.0 = already waiting when the engine starts).

    ``source``: optional [S, d] float32 encoder-side features for
    cross-attention stacks (vlm patch embeds / audio frames) — rows may
    have *heterogeneous* lengths across a trace; the serving engines pad
    and mask. ``source_id``: dedup key for the source-KV pool — requests
    presenting the same id share one pooled encoder ingest (the engine
    never compares feature bytes, only this id); ``None`` means the source
    is private to this request."""
    prompt: np.ndarray                 # [P] int32 token ids
    max_new_tokens: int
    rid: int | str | None = None
    arrival: float = 0.0
    source: np.ndarray | None = None   # [S, d] float32 frontend features
    source_id: object = None           # hashable dedup key; None -> private
    ttft_deadline_s: float | None = None   # SLO: submit -> first token
    deadline_s: float | None = None        # SLO: submit -> last token

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size == 0:
            raise ValueError("empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if self.source is not None:
            self.source = np.asarray(self.source, np.float32)
            if self.source.ndim != 2:
                raise ValueError(f"source must be [S, d], got "
                                 f"{self.source.shape}")
        for name in ("ttft_deadline_s", "deadline_s"):
            v = getattr(self, name)
            if v is not None and not v > 0:
                raise ValueError(f"{name} must be > 0, got {v}")

    @property
    def budget(self) -> int:
        """Cache rows the request may touch: prompt + every generated token
        except the last (which is emitted without ever being fed back, so
        it gets no KV write)."""
        return len(self.prompt) + self.max_new_tokens - 1


@dataclass(eq=False)               # identity equality: used in remove()
class RequestState:
    request: Request
    status: str = QUEUED
    slot: int | None = None
    prefilled: int = 0                 # prompt tokens already chunk-prefilled
    tokens: list = field(default_factory=list)
    token_times: list = field(default_factory=list)
    t_submit: float = 0.0
    t_admit: float | None = None
    t_first: float | None = None
    t_done: float | None = None
    finish_reason: str = ""
    code: str = ""                     # machine-readable terminal code
    degraded_from: int | None = None   # original max_new_tokens pre-degrade

    @property
    def rid(self):
        return self.request.rid

    @property
    def ttft(self) -> float | None:
        """Submit -> first emitted token (includes queueing delay)."""
        return None if self.t_first is None else self.t_first - self.t_submit

    @property
    def remaining(self) -> int:
        """Tokens still owed under the request's budget — the adaptive
        decode tick horizon is capped by the min of this over active rows
        (a row's on-device budget counter retires it at exactly this many
        more ticks, so any further fused ticks would run fully parked)."""
        return self.request.max_new_tokens - len(self.tokens)

    @property
    def itl_ms(self) -> list:
        ts = self.token_times
        return [1e3 * (b - a) for a, b in zip(ts, ts[1:])]


class Scheduler:
    """``on_event``: optional telemetry sink (``sink(kind, t=..., **data)``)
    for the queue-side lifecycle events the scheduler owns — ``enqueue`` /
    ``reject`` / ``shed`` / ``degrade`` at submit and ``admit`` (plus
    ``backfill`` when the allocated slot was freed earlier in this run) —
    so a trace shows queueing delay, slot reuse, and overload decisions
    without the engine re-deriving any of them.

    ``overload``: optional :class:`OverloadConfig`; when set the FIFO is
    bounded and overflow is resolved by the configured shed policy. When
    ``None`` (default) the queue is unbounded and ``submit`` behaves
    exactly as before overload control existed."""

    def __init__(self, pool: KVSlotPool, on_event=None,
                 overload: OverloadConfig | None = None):
        self.pool = pool
        self.overload = overload
        self.queue: deque[RequestState] = deque()
        self.prefilling: list[RequestState] = []
        self.decoding: dict[int, RequestState] = {}      # slot -> state
        self.retired: list[RequestState] = []
        self.rejected: list[RequestState] = []
        self.shed: list[RequestState] = []
        self.errored: list[RequestState] = []
        self._auto_rid = itertools.count()
        self._rids: set = set()
        self._sink = on_event
        self._recycled: set[int] = set()    # slots freed at least once
        self.n_submitted = 0
        self.n_admitted = 0
        self.n_retired = 0
        self.n_degraded = 0

    # ---- intake -----------------------------------------------------------
    def submit(self, request: Request, now: float = 0.0,
               reject=None, shed=None) -> RequestState:
        """``reject``: an engine-computed *infeasibility* reason for
        constraints the scheduler can't see (e.g. a source longer than the
        source-KV pool rows) — the request is recorded as rejected without
        queueing, through the same bookkeeping as a capacity rejection.
        ``shed``: an engine-computed *overload* reason (unattainable TTFT
        deadline, drain in progress) — the request is feasible but dropped,
        recorded as shed. Both accept a plain string or a
        ``(code, detail)`` pair."""
        if request.rid is None:
            while (rid := f"auto-{next(self._auto_rid)}") in self._rids:
                pass
            request.rid = rid
        if request.rid in self._rids:
            raise ValueError(f"duplicate request id {request.rid!r}")
        self._rids.add(request.rid)
        state = RequestState(request=request, t_submit=now)
        self.n_submitted += 1
        if reject is None and not self.pool.fits(request.budget):
            reject = ("budget_too_large",
                      f"rejected: needs {request.budget} rows > "
                      f"slot capacity {self.pool.capacity}")
        if reject is not None:
            code, detail = _reason(reject, "infeasible")
            state.status = REJECTED
            state.finish_reason = detail
            state.code = code
            state.t_done = now
            self.rejected.append(state)
            if self._sink is not None:
                self._sink("reject", t=now, rid=state.rid, code=code,
                           reason=detail)
            return state
        if shed is None and self.overload is not None:
            shed = self._apply_overload(state, now)
        if shed is not None:
            code, detail = _reason(shed, "shed")
            self._mark_shed(state, code, detail, now)
            return state
        self.queue.append(state)
        if self._sink is not None:
            self._sink("enqueue", t=now, rid=state.rid,
                       queue_depth=len(self.queue))
        return state

    # ---- overload control --------------------------------------------------
    def _apply_overload(self, incoming: RequestState, now: float):
        """Resolve a queue overflow per the configured policy. Returns a
        shed reason for the *incoming* request, or ``None`` if it may be
        enqueued (possibly after shedding or degrading others)."""
        cfg = self.overload
        if len(self.queue) < cfg.max_queue:
            return None
        if cfg.policy == "reject":
            return ("queue_full",
                    f"shed: queue full ({len(self.queue)} >= "
                    f"{cfg.max_queue}, policy=reject)")
        if cfg.policy == "shed-oldest":
            victim = self.queue.popleft()
            self._mark_shed(
                victim, "queue_full",
                f"shed: oldest queued dropped for {incoming.rid!r} "
                f"(queue {cfg.max_queue} full, policy=shed-oldest)", now)
            return None
        # degrade: shrink everyone's decode budget; queue depth may grow.
        for st in list(self.queue) + [incoming]:
            req = st.request
            new = max(1, int(req.max_new_tokens * cfg.degrade_factor))
            if new == req.max_new_tokens:
                continue
            if st.degraded_from is None:
                st.degraded_from = req.max_new_tokens
            self.n_degraded += 1
            if self._sink is not None:
                self._sink("degrade", t=now, rid=st.rid,
                           from_tokens=req.max_new_tokens, to_tokens=new)
            req.max_new_tokens = new
        return None

    def _mark_shed(self, state: RequestState, code: str, detail: str,
                   now: float) -> None:
        state.status = SHED
        state.finish_reason = detail
        state.code = code
        state.t_done = now
        self.shed.append(state)
        if self._sink is not None:
            self._sink("shed", t=now, rid=state.rid, code=code,
                       reason=detail)

    def shed_queued(self, state: RequestState, code: str, now: float,
                    detail: str | None = None) -> None:
        """Shed a request that is still QUEUED (deadline expiry while
        waiting, client cancellation, drain). The request never held a
        slot, so there is nothing to reclaim."""
        assert state.status == QUEUED, state.status
        self.queue.remove(state)
        self._mark_shed(state, code, detail or f"shed: {code}", now)

    def admit(self, now: float) -> list[RequestState]:
        """Backfill free slots from the queue (FIFO). Called at the top of
        every engine step, so a slot freed by a retirement is backfilled at
        the following step and never idles while work is waiting."""
        newly = []
        while self.queue and self.pool.n_free:
            state = self.queue.popleft()
            state.slot = self.pool.alloc(state.rid)
            state.status = PREFILLING
            state.t_admit = now
            self.n_admitted += 1
            self.prefilling.append(state)
            newly.append(state)
            if self._sink is not None:
                self._sink("admit", t=now, rid=state.rid, slot=state.slot,
                           queued_s=round(now - state.t_submit, 6))
                if state.slot in self._recycled:
                    self._sink("backfill", t=now, rid=state.rid,
                               slot=state.slot)
        return newly

    # ---- transitions ------------------------------------------------------
    def start_decoding(self, state: RequestState) -> None:
        assert state.status == PREFILLING and state.slot is not None
        self.prefilling.remove(state)
        self.pool.set_length(state.slot, len(state.request.prompt))
        state.status = DECODING
        self.decoding[state.slot] = state

    def retire(self, state: RequestState, reason: str, now: float,
               code: str | None = None) -> int:
        """Free the slot and record the outcome; returns the freed slot so
        the engine can reset the device-side cache entry."""
        assert state.status == DECODING
        slot = state.slot
        self.decoding.pop(slot)
        self.pool.release(slot)
        state.status = RETIRED
        state.finish_reason = reason
        state.code = code if code is not None else reason
        state.t_done = now
        state.slot = None
        self.retired.append(state)
        self.n_retired += 1
        self._recycled.add(slot)
        return slot

    def abort(self, state: RequestState, code: str, now: float, *,
              error: bool = False, detail: str | None = None) -> int:
        """Stop a request that currently *holds a slot* (PREFILLING or
        DECODING) before its natural end, freeing the slot. With
        ``error=False`` the request retires normally with the given code
        (deadline miss, cancellation, drain) and keeps any tokens already
        generated; with ``error=True`` it terminates as ERRORED (typed
        fault — poisoned logits, failed source ingest). Returns the freed
        slot so the engine can reset the device-side cache entry (errored
        requests do **not** count toward ``n_retired``: conservation
        tracks them separately so a clean run pins ``n_retired ==
        len(trace)`` exactly)."""
        assert state.status in (PREFILLING, DECODING), state.status
        slot = state.slot
        if state.status == PREFILLING:
            self.prefilling.remove(state)
        else:
            self.decoding.pop(slot)
        self.pool.release(slot)
        state.finish_reason = detail or code
        state.code = code
        state.t_done = now
        state.slot = None
        if error:
            state.status = ERRORED
            self.errored.append(state)
        else:
            state.status = RETIRED
            self.retired.append(state)
            self.n_retired += 1
        self._recycled.add(slot)
        return slot

    def reset_stats(self) -> None:
        """Forget finished-traffic history (retired / rejected records, their
        rids, and the counters) while keeping live state — queue, prefilling,
        decoding, slot ownership — intact. Used by engine warmup so reports
        cover only real traffic."""
        self.retired.clear()
        self.rejected.clear()
        self.shed.clear()
        self.errored.clear()
        self._recycled.clear()   # a post-reset admit is a fresh alloc again
        self._rids = {s.rid for s in self.all_states()}
        self.n_submitted = (len(self.queue) + len(self.prefilling)
                            + len(self.decoding))
        self.n_admitted = len(self.prefilling) + len(self.decoding)
        self.n_retired = 0
        self.n_degraded = 0

    # ---- queries ----------------------------------------------------------
    def pending(self) -> bool:
        return bool(self.queue or self.prefilling or self.decoding)

    def all_states(self) -> Iterable[RequestState]:
        return itertools.chain(self.queue, self.prefilling,
                               self.decoding.values(), self.retired,
                               self.rejected, self.shed, self.errored)

    def assert_conservation(self) -> None:
        """Every submitted request is in exactly one bucket; every admitted
        request reached exactly one of retired / errored; terminal records
        carry their typed code; no slot leaks."""
        in_flight = (len(self.queue) + len(self.prefilling)
                     + len(self.decoding))
        assert self.n_submitted == (in_flight + len(self.retired)
                                    + len(self.rejected) + len(self.shed)
                                    + len(self.errored)), vars(self)
        assert self.n_admitted == (len(self.prefilling) + len(self.decoding)
                                   + self.n_retired + len(self.errored))
        assert self.n_retired == len(self.retired)
        assert self.pool.n_used == len(self.prefilling) + len(self.decoding)
        for bucket in (self.retired, self.rejected, self.shed, self.errored):
            for st in bucket:
                assert st.code, f"terminal state without code: {st.rid!r}"
                assert st.slot is None, f"terminal state holds a slot: " \
                                        f"{st.rid!r}"
        rids = [s.rid for s in self.all_states()]
        assert len(rids) == len(set(rids)), "request tracked twice"
        self.pool.assert_consistent()
