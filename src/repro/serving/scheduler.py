"""Continuous-batching request scheduler.

Lifecycle of a request::

    submit -> QUEUED -> (slot alloc) PREFILLING -> DECODING -> RETIRED
                 \\-> REJECTED (prompt + budget exceed slot capacity)

The scheduler owns the host-side bookkeeping only: the FIFO admission queue,
slot assignment from the :class:`KVSlotPool`, per-request token ledgers and
timing, and retirement (EOS / max-token) with prompt backfill — a freed slot
is handed to the next queued request at the following engine step's
admission, so it never idles while work is waiting. All device work (chunked
prefill, ragged decode, cache resets, source-KV ingest for cross-attention
requests) lives in :mod:`repro.serving.continuous`; the engine may also
veto a request at submit time with a precomputed ``reject`` reason (e.g. a
source longer than the source-KV pool rows), which flows through the same
rejection bookkeeping as a slot-capacity miss.

Conservation invariant (checked by ``assert_conservation``): every submitted
request is in exactly one of queued / prefilling / decoding / retired /
rejected, every admitted request retires exactly once, and no slot leaks.
"""
from __future__ import annotations

import itertools
from collections import deque
from dataclasses import dataclass, field
from typing import Iterable

import numpy as np

from .slot_pool import KVSlotPool

QUEUED, PREFILLING, DECODING, RETIRED, REJECTED = (
    "queued", "prefilling", "decoding", "retired", "rejected")


@dataclass(eq=False)               # identity equality: prompts are arrays
class Request:
    """One generation request. ``arrival`` is seconds on the engine clock
    (0.0 = already waiting when the engine starts).

    ``source``: optional [S, d] float32 encoder-side features for
    cross-attention stacks (vlm patch embeds / audio frames) — rows may
    have *heterogeneous* lengths across a trace; the serving engines pad
    and mask. ``source_id``: dedup key for the source-KV pool — requests
    presenting the same id share one pooled encoder ingest (the engine
    never compares feature bytes, only this id); ``None`` means the source
    is private to this request."""
    prompt: np.ndarray                 # [P] int32 token ids
    max_new_tokens: int
    rid: int | str | None = None
    arrival: float = 0.0
    source: np.ndarray | None = None   # [S, d] float32 frontend features
    source_id: object = None           # hashable dedup key; None -> private

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size == 0:
            raise ValueError("empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if self.source is not None:
            self.source = np.asarray(self.source, np.float32)
            if self.source.ndim != 2:
                raise ValueError(f"source must be [S, d], got "
                                 f"{self.source.shape}")

    @property
    def budget(self) -> int:
        """Cache rows the request may touch: prompt + every generated token
        except the last (which is emitted without ever being fed back, so
        it gets no KV write)."""
        return len(self.prompt) + self.max_new_tokens - 1


@dataclass(eq=False)               # identity equality: used in remove()
class RequestState:
    request: Request
    status: str = QUEUED
    slot: int | None = None
    prefilled: int = 0                 # prompt tokens already chunk-prefilled
    tokens: list = field(default_factory=list)
    token_times: list = field(default_factory=list)
    t_submit: float = 0.0
    t_admit: float | None = None
    t_first: float | None = None
    t_done: float | None = None
    finish_reason: str = ""

    @property
    def rid(self):
        return self.request.rid

    @property
    def ttft(self) -> float | None:
        """Submit -> first emitted token (includes queueing delay)."""
        return None if self.t_first is None else self.t_first - self.t_submit

    @property
    def remaining(self) -> int:
        """Tokens still owed under the request's budget — the adaptive
        decode tick horizon is capped by the min of this over active rows
        (a row's on-device budget counter retires it at exactly this many
        more ticks, so any further fused ticks would run fully parked)."""
        return self.request.max_new_tokens - len(self.tokens)

    @property
    def itl_ms(self) -> list:
        ts = self.token_times
        return [1e3 * (b - a) for a, b in zip(ts, ts[1:])]


class Scheduler:
    """``on_event``: optional telemetry sink (``sink(kind, t=..., **data)``)
    for the queue-side lifecycle events the scheduler owns — ``enqueue`` /
    ``reject`` at submit and ``admit`` (plus ``backfill`` when the
    allocated slot was freed earlier in this run) — so a trace shows
    queueing delay and slot reuse without the engine re-deriving either."""

    def __init__(self, pool: KVSlotPool, on_event=None):
        self.pool = pool
        self.queue: deque[RequestState] = deque()
        self.prefilling: list[RequestState] = []
        self.decoding: dict[int, RequestState] = {}      # slot -> state
        self.retired: list[RequestState] = []
        self.rejected: list[RequestState] = []
        self._auto_rid = itertools.count()
        self._rids: set = set()
        self._sink = on_event
        self._recycled: set[int] = set()    # slots freed at least once
        self.n_submitted = 0
        self.n_admitted = 0
        self.n_retired = 0

    # ---- intake -----------------------------------------------------------
    def submit(self, request: Request, now: float = 0.0,
               reject: str | None = None) -> RequestState:
        """``reject``: an engine-computed rejection reason for constraints
        the scheduler can't see (e.g. a source longer than the source-KV
        pool rows) — the request is recorded as rejected without queueing,
        through the same bookkeeping as a capacity rejection."""
        if request.rid is None:
            while (rid := f"auto-{next(self._auto_rid)}") in self._rids:
                pass
            request.rid = rid
        if request.rid in self._rids:
            raise ValueError(f"duplicate request id {request.rid!r}")
        self._rids.add(request.rid)
        state = RequestState(request=request, t_submit=now)
        self.n_submitted += 1
        if reject is None and not self.pool.fits(request.budget):
            reject = (f"rejected: needs {request.budget} rows > "
                      f"slot capacity {self.pool.capacity}")
        if reject is not None:
            state.status = REJECTED
            state.finish_reason = reject
            state.t_done = now
            self.rejected.append(state)
            if self._sink is not None:
                self._sink("reject", t=now, rid=state.rid, reason=reject)
            return state
        self.queue.append(state)
        if self._sink is not None:
            self._sink("enqueue", t=now, rid=state.rid,
                       queue_depth=len(self.queue))
        return state

    def admit(self, now: float) -> list[RequestState]:
        """Backfill free slots from the queue (FIFO). Called at the top of
        every engine step, so a slot freed by a retirement is backfilled at
        the following step and never idles while work is waiting."""
        newly = []
        while self.queue and self.pool.n_free:
            state = self.queue.popleft()
            state.slot = self.pool.alloc(state.rid)
            state.status = PREFILLING
            state.t_admit = now
            self.n_admitted += 1
            self.prefilling.append(state)
            newly.append(state)
            if self._sink is not None:
                self._sink("admit", t=now, rid=state.rid, slot=state.slot,
                           queued_s=round(now - state.t_submit, 6))
                if state.slot in self._recycled:
                    self._sink("backfill", t=now, rid=state.rid,
                               slot=state.slot)
        return newly

    # ---- transitions ------------------------------------------------------
    def start_decoding(self, state: RequestState) -> None:
        assert state.status == PREFILLING and state.slot is not None
        self.prefilling.remove(state)
        self.pool.set_length(state.slot, len(state.request.prompt))
        state.status = DECODING
        self.decoding[state.slot] = state

    def retire(self, state: RequestState, reason: str, now: float) -> int:
        """Free the slot and record the outcome; returns the freed slot so
        the engine can reset the device-side cache entry."""
        assert state.status == DECODING
        slot = state.slot
        self.decoding.pop(slot)
        self.pool.release(slot)
        state.status = RETIRED
        state.finish_reason = reason
        state.t_done = now
        state.slot = None
        self.retired.append(state)
        self.n_retired += 1
        self._recycled.add(slot)
        return slot

    def reset_stats(self) -> None:
        """Forget finished-traffic history (retired / rejected records, their
        rids, and the counters) while keeping live state — queue, prefilling,
        decoding, slot ownership — intact. Used by engine warmup so reports
        cover only real traffic."""
        self.retired.clear()
        self.rejected.clear()
        self._recycled.clear()   # a post-reset admit is a fresh alloc again
        self._rids = {s.rid for s in self.all_states()}
        self.n_submitted = (len(self.queue) + len(self.prefilling)
                            + len(self.decoding))
        self.n_admitted = len(self.prefilling) + len(self.decoding)
        self.n_retired = 0

    # ---- queries ----------------------------------------------------------
    def pending(self) -> bool:
        return bool(self.queue or self.prefilling or self.decoding)

    def all_states(self) -> Iterable[RequestState]:
        return itertools.chain(self.queue, self.prefilling,
                               self.decoding.values(), self.retired,
                               self.rejected)

    def assert_conservation(self) -> None:
        in_flight = (len(self.queue) + len(self.prefilling)
                     + len(self.decoding))
        assert self.n_submitted == (in_flight + len(self.retired)
                                    + len(self.rejected)), vars(self)
        assert self.n_admitted == (len(self.prefilling) + len(self.decoding)
                                   + self.n_retired)
        assert self.n_retired == len(self.retired)
        assert self.pool.n_used == len(self.prefilling) + len(self.decoding)
        rids = [s.rid for s in self.all_states()]
        assert len(rids) == len(set(rids)), "request tracked twice"
        self.pool.assert_consistent()
