"""KV slot pool + source-KV pool: the host-side ledgers of continuous
batching (see ``docs/serving.md`` for the full lifecycle diagram).

Continuous batching keeps the jit'd decode step at a static ``[n_slots]``
batch shape while request membership changes every step. :class:`KVSlotPool`
is the host-side ledger over the model's preallocated decode cache
(``model.init_cache(n_slots, max_len)``): slot ``s`` owns rows
``cache[k|v][:, s, :]`` plus its entries of ``cache['len']`` and the RoPE
angle state.

Layout contract with :meth:`TransformerLM.decode_step`'s ragged form:

* the **final cache row** (index ``max_len - 1``) is reserved as the parking
  position for the masked KV writes of inactive slots, so a request is only
  admissible if ``prompt_len + max_new_tokens <= capacity`` where
  ``capacity = max_len - 1``. Ring KV caches (``kv_ring`` SWA configs) have
  no parkable dead row — every ring slot is, or wraps into, a live window
  position — so their inactive slots park via a per-slot **write mask**
  (the row rewrites its old value in place; ``TransformerLM._write_kv``
  ``active=``). The tail reservation still prices admission for rings:
  ``capacity`` bounds a request's *position* budget (``cache['len']`` /
  RoPE state run over absolute positions), which is ``max_len``-scaled even
  when the live KV working set is only ``ring_len`` rows;
* release resets the slot's ledger length (and the device ``len`` entry via
  :meth:`TransformerLM.release_slot`), so nothing in a freed slot's KV rows
  is ever attended again — the next occupant's chunked prefill overwrites
  the contents in place (reset-on-release). Recurrent-state families
  (ssm / hybrid) additionally zero the slot's per-row state (RWKV
  x_prev/wkv, Mamba conv/ssm) on release: unlike KV rows it feeds forward
  multiplicatively, so the next occupant must start from the empty-context
  state rather than merely ignoring stale rows.

:class:`SourceKVPool` is the second ledger, for **cross-attention stacks**
(vlm / audio): the encoder-side K/V a request's decoder cross-attends to.
Unlike self-attention KV it is written exactly once (at admission, via
``TransformerLM.ingest_source``) and *read-only* for the request's whole
lifetime, so it pools by **source id** with reference counting — N requests
decoding against the same image / audio clip share one device entry (the
encoder runs once, not N times), and ``cache['src_index']`` maps each slot
to its entry. The entry's device rows are zeroed only when its refcount
drops to zero (``TransformerLM.release_source``), so a backfilled request
can never read its predecessor's encoder state: the predecessor's entry is
either still alive (held by another sharing request, and the new occupant's
``src_index`` points elsewhere) or zeroed.
"""
from __future__ import annotations

from typing import Any, Hashable

RESERVED_TAIL = 1   # parking row for masked decode writes of inactive slots


class SlotPoolError(RuntimeError):
    """Misuse of the pool (double release, unknown slot, ...)."""


class KVSlotPool:
    def __init__(self, n_slots: int, max_len: int):
        if n_slots < 1:
            raise SlotPoolError(f"n_slots must be >= 1, got {n_slots}")
        if max_len <= RESERVED_TAIL:
            raise SlotPoolError(f"max_len must exceed {RESERVED_TAIL}")
        self.n_slots = n_slots
        self.max_len = max_len
        self.capacity = max_len - RESERVED_TAIL
        self._free = list(range(n_slots - 1, -1, -1))   # pop() -> slot 0 first
        self._owner: dict[int, Hashable] = {}
        self._length = [0] * n_slots
        self.total_allocs = 0
        self.total_releases = 0

    # ---- queries ----------------------------------------------------------
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_used(self) -> int:
        return self.n_slots - len(self._free)

    def fits(self, tokens: int) -> bool:
        """Can a request needing ``tokens`` cache rows ever be admitted?"""
        return 0 < tokens <= self.capacity

    def owner(self, slot: int) -> Hashable:
        return self._owner.get(slot)

    def length(self, slot: int) -> int:
        return self._length[slot]

    def used_slots(self) -> dict[int, Hashable]:
        """Snapshot of ``slot -> owner`` for every allocated slot (the
        auditor cross-checks this against the scheduler's view)."""
        return dict(self._owner)

    def occupancy(self) -> float:
        return self.n_used / self.n_slots

    # ---- alloc / release --------------------------------------------------
    def alloc(self, owner: Hashable) -> int | None:
        """Take a slot off the free list for ``owner``; None when exhausted."""
        if not self._free:
            return None
        slot = self._free.pop()
        self._owner[slot] = owner
        self._length[slot] = 0
        self.total_allocs += 1
        return slot

    def release(self, slot: int) -> Hashable:
        """Return a slot to the free list (reset-on-release). The caller is
        responsible for the matching device-side reset
        (:meth:`TransformerLM.release_slot`)."""
        if slot not in self._owner:
            raise SlotPoolError(f"release of unowned slot {slot}")
        owner = self._owner.pop(slot)
        self._length[slot] = 0
        self._free.append(slot)
        self.total_releases += 1
        return owner

    def set_length(self, slot: int, length: int) -> None:
        if slot not in self._owner:
            raise SlotPoolError(f"set_length on unowned slot {slot}")
        if not 0 <= length <= self.capacity:
            raise SlotPoolError(f"length {length} outside [0, {self.capacity}]")
        self._length[slot] = length

    def advance(self, slot: int) -> int:
        """One decode step appended one KV row for this slot."""
        self.set_length(slot, self._length[slot] + 1)
        return self._length[slot]

    def reset_stats(self) -> None:
        """Zero the lifetime counters without touching allocation state
        (keeps ``total_allocs - total_releases == slots in use``)."""
        self.total_allocs = len(self._owner)
        self.total_releases = 0

    # ---- invariants -------------------------------------------------------
    def assert_consistent(self) -> None:
        assert len(self._free) + len(self._owner) == self.n_slots, \
            (self._free, self._owner)
        assert len(set(self._free)) == len(self._free), "free-list duplicates"
        assert not (set(self._free) & set(self._owner)), "slot both free+owned"
        assert self.total_allocs - self.total_releases == len(self._owner)
        for slot in self._free:
            assert self._length[slot] == 0, f"freed slot {slot} keeps length"


class SourceKVPool:
    """Refcounted pool of encoder-side (source) K/V entries, keyed by
    source id.

    Entry ``e`` owns the device rows ``cache['src_k'|'src_v'][:, e]`` and
    ``cache['src_len'][e]``. ``acquire(source_id)`` either bumps an existing
    entry's refcount (the source is already resident — N requests share one
    encoder ingest) or takes a fresh entry off the free list; ``release``
    drops a reference and hands the entry back for zeroing
    (``TransformerLM.release_source``) only when the last holder retires.

    Capacity note: with ``n_entries == n_slots`` (the continuous engine's
    default) acquisition can never fail while a slot is free — each live
    request holds at most one reference, so entries in use <= slots in use,
    and sharing only loosens that bound. A smaller pool would need an
    admission gate; a larger one is pure dedup headroom.

    ``on_event``: optional telemetry sink (``sink(kind, **data)``) the
    ledger calls at its three state changes — ``source_ingest`` (fresh
    entry acquired; the caller will run the encoder), ``source_share``
    (acquisition served by refcount on a resident entry) and
    ``source_release`` (last holder retired; the entry goes back for
    zeroing) — each carrying the source id, entry index, refcount, and the
    acquiring/releasing ``owner`` (the request id, when the caller passes
    one). This makes "which requests shared an encoder entry" a property
    of the trace itself rather than something inferred from the engine's
    aggregate ``source_ingests`` / ``source_shares`` counters.
    """

    def __init__(self, n_entries: int, src_max: int, on_event=None):
        if n_entries < 1:
            raise SlotPoolError(f"n_entries must be >= 1, got {n_entries}")
        if src_max < 1:
            raise SlotPoolError(f"src_max must be >= 1, got {src_max}")
        self.n_entries = n_entries
        self.src_max = src_max              # rows per entry (pad-to length)
        self._free = list(range(n_entries - 1, -1, -1))   # pop() -> entry 0
        self._entry: dict[Hashable, int] = {}             # source id -> entry
        self._refs: dict[int, int] = {}                   # entry -> refcount
        self._sid: dict[int, Hashable] = {}               # entry -> source id
        self._sink = on_event               # telemetry sink; None -> silent
        self.total_ingests = 0              # fresh entries (encoder ran)
        self.total_shares = 0               # acquisitions served by sharing

    # ---- queries ----------------------------------------------------------
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_used(self) -> int:
        return self.n_entries - len(self._free)

    def fits(self, source_rows: int) -> bool:
        """Can a source needing ``source_rows`` K/V rows ever be ingested?
        (Zero rows — a request with no source — always fits: it still takes
        an entry, whose ``src_len`` stays 0 so every read masks to zero.)"""
        return 0 <= source_rows <= self.src_max

    def entry_of(self, source_id: Hashable) -> int | None:
        return self._entry.get(source_id)

    def refcount(self, entry: int) -> int:
        return self._refs.get(entry, 0)

    def total_refs(self) -> int:
        """Live references across all entries — must equal the number of
        requests currently holding a source (refcount conservation; the
        auditor checks it against the engine's rid -> source-id ledger)."""
        return sum(self._refs.values())

    # ---- acquire / release ------------------------------------------------
    def acquire(self, source_id: Hashable,
                owner: Hashable = None) -> tuple[int | None, bool]:
        """Returns ``(entry, fresh)``: ``fresh=True`` means the caller must
        ingest the source's K/V into the entry's device rows; ``fresh=False``
        means the source is already resident and this request shares it.
        ``(None, False)`` when the pool is exhausted. ``owner`` (typically
        the request id) rides along on the ledger's telemetry events."""
        entry = self._entry.get(source_id)
        if entry is not None:
            self._refs[entry] += 1
            self.total_shares += 1
            if self._sink is not None:
                self._sink("source_share", rid=owner, entry=entry,
                           source_id=source_id, refcount=self._refs[entry])
            return entry, False
        if not self._free:
            return None, False
        entry = self._free.pop()
        self._entry[source_id] = entry
        self._refs[entry] = 1
        self._sid[entry] = source_id
        self.total_ingests += 1
        if self._sink is not None:
            self._sink("source_ingest", rid=owner, entry=entry,
                       source_id=source_id, refcount=1)
        return entry, True

    def release(self, source_id: Hashable,
                owner: Hashable = None) -> int | None:
        """Drop one reference. Returns the freed entry index when the last
        reference went away — the caller must then zero the entry's device
        rows (``TransformerLM.release_source``) — else None."""
        entry = self._entry.get(source_id)
        if entry is None:
            raise SlotPoolError(f"release of unknown source id {source_id!r}")
        self._refs[entry] -= 1
        if self._refs[entry] > 0:
            return None
        del self._refs[entry]
        del self._entry[source_id]
        del self._sid[entry]
        self._free.append(entry)
        if self._sink is not None:
            # zeroing event: the caller is about to reset the device rows
            self._sink("source_release", rid=owner, entry=entry,
                       source_id=source_id, refcount=0)
        return entry

    def reset_stats(self) -> None:
        self.total_ingests = len(self._entry)
        self.total_shares = 0

    # ---- invariants -------------------------------------------------------
    def assert_consistent(self) -> None:
        assert len(self._free) + len(self._entry) == self.n_entries, \
            (self._free, self._entry)
        assert len(set(self._free)) == len(self._free), "free-list duplicates"
        assert set(self._entry.values()) == set(self._refs), "ledger skew"
        assert not (set(self._free) & set(self._refs)), "entry both free+held"
        assert all(r > 0 for r in self._refs.values()), "zero-ref entry held"
