"""KV slot pool: fixed pool of cache slots with free-list allocation.

Continuous batching keeps the jit'd decode step at a static ``[n_slots]``
batch shape while request membership changes every step. The pool is the
host-side ledger over the model's preallocated decode cache
(``model.init_cache(n_slots, max_len)``): slot ``s`` owns rows
``cache[k|v][:, s, :]`` plus its entries of ``cache['len']`` and the RoPE
angle state.

Layout contract with :meth:`TransformerLM.decode_step`'s ragged form:

* the **final cache row** (index ``max_len - 1``) is reserved as the parking
  position for the masked KV writes of inactive slots, so a request is only
  admissible if ``prompt_len + max_new_tokens <= capacity`` where
  ``capacity = max_len - 1``. Ring KV caches (``kv_ring`` SWA configs) have
  no parkable dead row — every ring slot is, or wraps into, a live window
  position — so their inactive slots park via a per-slot **write mask**
  (the row rewrites its old value in place; ``TransformerLM._write_kv``
  ``active=``). The tail reservation still prices admission for rings:
  ``capacity`` bounds a request's *position* budget (``cache['len']`` /
  RoPE state run over absolute positions), which is ``max_len``-scaled even
  when the live KV working set is only ``ring_len`` rows;
* release resets the slot's ledger length (and the device ``len`` entry via
  :meth:`TransformerLM.release_slot`), so nothing in a freed slot's KV rows
  is ever attended again — the next occupant's chunked prefill overwrites
  the contents in place (reset-on-release). Recurrent-state families
  (ssm / hybrid) additionally zero the slot's per-row state (RWKV
  x_prev/wkv, Mamba conv/ssm) on release: unlike KV rows it feeds forward
  multiplicatively, so the next occupant must start from the empty-context
  state rather than merely ignoring stale rows.
"""
from __future__ import annotations

from typing import Any, Hashable

RESERVED_TAIL = 1   # parking row for masked decode writes of inactive slots


class SlotPoolError(RuntimeError):
    """Misuse of the pool (double release, unknown slot, ...)."""


class KVSlotPool:
    def __init__(self, n_slots: int, max_len: int):
        if n_slots < 1:
            raise SlotPoolError(f"n_slots must be >= 1, got {n_slots}")
        if max_len <= RESERVED_TAIL:
            raise SlotPoolError(f"max_len must exceed {RESERVED_TAIL}")
        self.n_slots = n_slots
        self.max_len = max_len
        self.capacity = max_len - RESERVED_TAIL
        self._free = list(range(n_slots - 1, -1, -1))   # pop() -> slot 0 first
        self._owner: dict[int, Hashable] = {}
        self._length = [0] * n_slots
        self.total_allocs = 0
        self.total_releases = 0

    # ---- queries ----------------------------------------------------------
    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_used(self) -> int:
        return self.n_slots - len(self._free)

    def fits(self, tokens: int) -> bool:
        """Can a request needing ``tokens`` cache rows ever be admitted?"""
        return 0 < tokens <= self.capacity

    def owner(self, slot: int) -> Hashable:
        return self._owner.get(slot)

    def length(self, slot: int) -> int:
        return self._length[slot]

    def occupancy(self) -> float:
        return self.n_used / self.n_slots

    # ---- alloc / release --------------------------------------------------
    def alloc(self, owner: Hashable) -> int | None:
        """Take a slot off the free list for ``owner``; None when exhausted."""
        if not self._free:
            return None
        slot = self._free.pop()
        self._owner[slot] = owner
        self._length[slot] = 0
        self.total_allocs += 1
        return slot

    def release(self, slot: int) -> Hashable:
        """Return a slot to the free list (reset-on-release). The caller is
        responsible for the matching device-side reset
        (:meth:`TransformerLM.release_slot`)."""
        if slot not in self._owner:
            raise SlotPoolError(f"release of unowned slot {slot}")
        owner = self._owner.pop(slot)
        self._length[slot] = 0
        self._free.append(slot)
        self.total_releases += 1
        return owner

    def set_length(self, slot: int, length: int) -> None:
        if slot not in self._owner:
            raise SlotPoolError(f"set_length on unowned slot {slot}")
        if not 0 <= length <= self.capacity:
            raise SlotPoolError(f"length {length} outside [0, {self.capacity}]")
        self._length[slot] = length

    def advance(self, slot: int) -> int:
        """One decode step appended one KV row for this slot."""
        self.set_length(slot, self._length[slot] + 1)
        return self._length[slot]

    def reset_stats(self) -> None:
        """Zero the lifetime counters without touching allocation state
        (keeps ``total_allocs - total_releases == slots in use``)."""
        self.total_allocs = len(self._owner)
        self.total_releases = 0

    # ---- invariants -------------------------------------------------------
    def assert_consistent(self) -> None:
        assert len(self._free) + len(self._owner) == self.n_slots, \
            (self._free, self._owner)
        assert len(set(self._free)) == len(self._free), "free-list duplicates"
        assert not (set(self._free) & set(self._owner)), "slot both free+owned"
        assert self.total_allocs - self.total_releases == len(self._owner)
        for slot in self._free:
            assert self._length[slot] == 0, f"freed slot {slot} keeps length"
