"""Serving telemetry: structured lifecycle events, per-block engine gauges,
and mergeable log-bucket latency histograms.

The continuous engine's end-of-run ``report()`` answers *what* happened
(aggregate throughput, dispatch counts); this module answers *where the
time went* and *why*: every request emits typed lifecycle events with
monotonic timestamps (seconds on the engine clock, i.e. relative to the
run's ``t0``), every decode block samples the engine's gauges (occupancy,
queue depth, free slots, live KV bytes, the chosen tick horizon K, and
**parked-tick waste** — ticks issued minus tokens emitted, the direct cost
of mid-block retirement that the eos-aware-horizon ROADMAP item would
recover), and the event stream converts to Chrome/Perfetto trace-event
format (:mod:`repro.serving.trace`) so prefill and decode dispatches render
as one timeline lane per slot.

Design constraints, in priority order:

* **Zero overhead when disabled.** The engine holds ``telemetry=None`` by
  default and every emission site is guarded (``if self._sink``), so the
  disabled path runs the exact pre-telemetry host loop — byte-identical
  tokens, no event objects, no callable indirection (tested in
  ``tests/test_telemetry.py``).
* **Events are host-side only.** Nothing here touches device code: an
  event records what the host already knew at a dispatch or sync site, so
  enabling telemetry cannot perturb compiled programs or token streams.
* **Bounded memory for latency stats.** :class:`LogHistogram` replaces the
  unbounded sorted-list percentiles: fixed log-spaced buckets, O(1) insert,
  mergeable across engines / runs, percentiles exact to within one bucket
  (~``10**(1/buckets_per_decade)`` relative width) of the nearest-rank
  value.

Event taxonomy (see docs/serving.md for the full table):

======================  =====================================================
kind                    emitted when
======================  =====================================================
``enqueue``             request accepted into the FIFO queue (scheduler)
``reject``              request refused at submit (capacity / source rules)
``admit``               queued request allocated a slot (scheduler)
``backfill``            the admit reused a slot freed earlier this run
``source_ingest``       source-KV pool entry freshly acquired (pool ledger)
``source_share``        acquisition served by refcount on a resident entry
``source_release``      last holder retired; entry handed back for zeroing
``prefill_chunk``       a slot advanced one prompt chunk (per slot, per
                        batched dispatch)
``first_token``         final chunk landed; token 0 sampled off the prefill
                        logits
``decode_block``        one K-tick fused decode dispatch + its host sync
``eos``                 request retired by sampling ``eos_id``
``budget_retire``       request retired by exhausting ``max_new_tokens``
``release``             slot's device state reset after retirement
``shed``                overload control dropped a request (queue full,
                        deadline expired in queue, cancel, drain) — carries
                        the typed ``code``
``degrade``             bounded-queue degrade policy shrank a queued
                        request's ``max_new_tokens``
``abort``               slot-holding request stopped early (deadline /
                        cancel / drain / interrupt) with partial tokens
``error_retire``        slot-holding request quarantined with a typed error
                        (non-finite logits, failed source ingest)
``fault``               an injected fault fired (``serving.faults``)
``drain``               engine entered graceful-shutdown drain mode
``gauges``              engine gauges sampled at a decode block's sync
======================  =====================================================

Every event carries ``t`` (engine-clock seconds) and, where meaningful,
``rid`` (request id), ``slot``, ``serial`` (admission serial) and ``block``
(decode/prefill dispatch index); kind-specific fields ride in ``data``.
"""
from __future__ import annotations

import json
import math
from collections import Counter
from dataclasses import dataclass, field
from pathlib import Path
from typing import IO, Iterable

LIFECYCLE_KINDS = (
    "enqueue", "reject", "admit", "backfill",
    "source_ingest", "source_share", "source_release",
    "prefill_chunk", "first_token", "decode_block",
    "eos", "budget_retire", "release",
    "shed", "degrade", "abort", "error_retire", "fault", "drain",
)
EVENT_KINDS = frozenset(LIFECYCLE_KINDS) | {"gauges"}


@dataclass(slots=True)
class Event:
    """One telemetry event. ``t`` is seconds on the engine clock (monotonic,
    relative to the run's ``t0`` — the same clock ``report()`` timestamps
    use). ``data`` holds the kind-specific payload (chunk offsets, tick
    horizon, gauge values, ...)."""
    kind: str
    t: float
    rid: object = None
    slot: int | None = None
    serial: int | None = None
    block: int | None = None
    data: dict = field(default_factory=dict)

    def to_json(self) -> dict:
        out = {"kind": self.kind, "t": round(self.t, 6)}
        for k in ("rid", "slot", "serial", "block"):
            v = getattr(self, k)
            if v is not None:
                out[k] = v
        if self.data:
            out["data"] = self.data
        return out


class LogHistogram:
    """Fixed-size log-bucket histogram for streaming latency percentiles.

    Bucket ``i`` covers ``[lo * g**i, lo * g**(i+1))`` with
    ``g = 10 ** (1 / buckets_per_decade)``; values below ``lo`` land in
    bucket 0, values at or above ``hi`` in the last bucket. Insert is O(1)
    and the memory is a fixed int list, so per-token ITL accounting stays
    bounded on arbitrarily long traces (the sorted-list percentiles this
    replaces grew one float per generated token).

    ``percentile(q)`` returns the geometric midpoint of the bucket holding
    the nearest-rank sample — within one bucket (a factor of ``g``) of the
    exact nearest-rank value, which is the contract
    ``tests/test_telemetry.py`` checks against ``_pct``.

    Histograms with identical bounds **merge** by adding counts
    (:meth:`merge`), so per-engine or per-run histograms aggregate exactly.
    """

    def __init__(self, lo: float = 1e-6, hi: float = 1e4,
                 buckets_per_decade: int = 16):
        if not (0 < lo < hi):
            raise ValueError(f"need 0 < lo < hi, got {lo}, {hi}")
        if buckets_per_decade < 1:
            raise ValueError("buckets_per_decade must be >= 1")
        self.lo, self.hi = float(lo), float(hi)
        self.bpd = buckets_per_decade
        self._log_g = math.log(10.0) / buckets_per_decade
        self.n_buckets = (int(math.ceil(
            (math.log(hi) - math.log(lo)) / self._log_g)) + 1)
        self.counts = [0] * self.n_buckets
        self.n = 0

    def _bucket(self, x: float) -> int:
        if x <= self.lo:
            return 0
        i = int((math.log(x) - math.log(self.lo)) / self._log_g)
        return min(i, self.n_buckets - 1)

    def add(self, x: float) -> None:
        self.counts[self._bucket(x)] += 1
        self.n += 1

    def edges(self, i: int) -> tuple[float, float]:
        lo = self.lo * math.exp(i * self._log_g)
        return lo, lo * math.exp(self._log_g)

    def percentile(self, q: float) -> float | None:
        """Nearest-rank percentile (same rank rule as ``_pct``: the sample
        at index ``ceil(q*n) - 1`` of the sorted stream), returned as the
        geometric midpoint of its bucket. None on an empty histogram."""
        if not self.n:
            return None
        rank = max(0, math.ceil(q * self.n) - 1)
        cum = 0
        for i, c in enumerate(self.counts):
            cum += c
            if cum > rank:
                a, b = self.edges(i)
                return math.sqrt(a * b)
        return self.edges(self.n_buckets - 1)[1]       # unreachable

    def merge(self, other: "LogHistogram") -> "LogHistogram":
        if (self.lo, self.hi, self.bpd) != (other.lo, other.hi, other.bpd):
            raise ValueError("histogram bounds differ; cannot merge")
        for i, c in enumerate(other.counts):
            self.counts[i] += c
        self.n += other.n
        return self

    def reset(self) -> None:
        self.counts = [0] * self.n_buckets
        self.n = 0


class Telemetry:
    """Event sink + gauge recorder for one engine.

    Pass an instance to ``ContinuousBatchingEngine(telemetry=...)``; the
    engine (and, through its ``on_event`` sinks, the scheduler and the
    source-KV pool ledgers) emit into it. ``run()`` resets the sink at
    entry — mirroring ``reset_stats`` — so after a run the stream covers
    exactly that run's traffic (warmup events are dropped).

    ``jsonl_path``: stream every event as one JSON line (truncated at each
    reset, so the file matches the in-memory stream). Convert with
    ``tools/trace_viewer.py`` or export directly via
    :meth:`write_chrome_trace`.
    """

    def __init__(self, jsonl_path: str | Path | None = None):
        self.events: list[Event] = []
        self._jsonl_path = Path(jsonl_path) if jsonl_path else None
        self._fh: IO | None = None

    # ---- emission ----------------------------------------------------------
    def emit(self, kind: str, *, t: float, rid=None, slot=None, serial=None,
             block=None, **data) -> Event:
        if kind not in EVENT_KINDS:
            raise ValueError(f"unknown event kind {kind!r}")
        ev = Event(kind=kind, t=t, rid=rid, slot=slot, serial=serial,
                   block=block, data=data)
        self.events.append(ev)
        if self._jsonl_path is not None:
            if self._fh is None:
                self._fh = self._jsonl_path.open("w")
            self._fh.write(json.dumps(ev.to_json()) + "\n")
        return ev

    # ---- queries -----------------------------------------------------------
    def counts(self) -> Counter:
        return Counter(ev.kind for ev in self.events)

    def by_kind(self, kind: str) -> list[Event]:
        return [ev for ev in self.events if ev.kind == kind]

    def by_rid(self, rid) -> list[Event]:
        return [ev for ev in self.events if ev.rid == rid]

    # ---- lifecycle ---------------------------------------------------------
    def reset(self) -> None:
        """Drop the recorded stream (and truncate the JSONL sink): called at
        each ``run()`` entry so a report's event stream covers exactly the
        reported traffic."""
        self.events.clear()
        if self._fh is not None:
            self._fh.close()
            self._fh = None
        if self._jsonl_path is not None and self._jsonl_path.exists():
            self._jsonl_path.write_text("")

    def flush(self) -> None:
        """Push buffered JSONL lines to disk without closing the sink —
        called at the end of every engine ``run()`` (including drain and
        interrupt exits) so the event tail is never lost."""
        if self._fh is not None:
            self._fh.flush()

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def __enter__(self) -> "Telemetry":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # ---- export ------------------------------------------------------------
    def to_chrome_trace(self) -> dict:
        from .trace import chrome_trace
        return chrome_trace(self.events)

    def write_chrome_trace(self, path: str | Path) -> Path:
        path = Path(path)
        path.write_text(json.dumps(self.to_chrome_trace()))
        return path


def load_events_jsonl(path: str | Path) -> list[Event]:
    """Rehydrate a JSONL event stream (the ``jsonl_path`` sink format) into
    :class:`Event` objects — what ``tools/trace_viewer.py`` feeds to the
    Chrome exporter."""
    events = []
    for line in Path(path).read_text().splitlines():
        if not line.strip():
            continue
        rec = json.loads(line)
        events.append(Event(kind=rec["kind"], t=rec["t"],
                            rid=rec.get("rid"), slot=rec.get("slot"),
                            serial=rec.get("serial"), block=rec.get("block"),
                            data=rec.get("data", {})))
    return events
