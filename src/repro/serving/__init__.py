from .engine import ServingEngine
from .slot_pool import KVSlotPool, SlotPoolError, SourceKVPool
from .scheduler import Request, RequestState, Scheduler
from .continuous import ContinuousBatchingEngine
from .workload import load_trace, poisson_trace

__all__ = ["ServingEngine", "ContinuousBatchingEngine", "KVSlotPool",
           "SlotPoolError", "SourceKVPool", "Request", "RequestState",
           "Scheduler", "load_trace", "poisson_trace"]
