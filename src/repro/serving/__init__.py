from .engine import ServingEngine
from .slot_pool import KVSlotPool, SlotPoolError, SourceKVPool
from .scheduler import (OverloadConfig, Request, RequestState, Scheduler)
from .telemetry import Event, LogHistogram, Telemetry, load_events_jsonl
from .trace import chrome_trace, write_chrome_trace
from .faults import Fault, FaultInjected, FaultPlan
from .audit import AuditViolation, EngineAuditor
from .continuous import ContinuousBatchingEngine
from .workload import load_trace, poisson_trace

__all__ = ["ServingEngine", "ContinuousBatchingEngine", "KVSlotPool",
           "SlotPoolError", "SourceKVPool", "OverloadConfig", "Request",
           "RequestState", "Scheduler", "Event", "LogHistogram",
           "Telemetry", "load_events_jsonl", "chrome_trace",
           "write_chrome_trace", "Fault", "FaultInjected", "FaultPlan",
           "AuditViolation", "EngineAuditor", "load_trace", "poisson_trace"]
