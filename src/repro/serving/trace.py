"""Chrome/Perfetto trace-event export for the serving telemetry stream.

Converts :class:`repro.serving.telemetry.Event` streams into the Chrome
trace-event JSON format (the ``traceEvents`` array form), loadable in
Perfetto (https://ui.perfetto.dev) or ``chrome://tracing``:

* **one timeline lane per slot** — slot ``s`` maps to tid ``s + 1``
  (stable for the whole trace); slot-bound events (``prefill_chunk``,
  ``decode_block`` slices, ``first_token``, retirements) land on their
  slot's lane, so a lane reads as the life of that slot: chunked prefill
  slices, then decode-block slices, punctuated by retire/backfill marks;
* **a scheduler lane** (tid 0) for pre-slot events — ``enqueue``,
  ``reject``, and the overload-control marks (``shed``, ``degrade``,
  ``drain``, slotless ``fault`` injections) — and the source-KV pool
  ledger events (which are keyed by entry, not slot); slot-bound
  robustness events (``abort``, ``error_retire``, slot-targeted
  ``fault``) land on the affected slot's lane, so a quarantine reads in
  place: the decode-block slice, the fault mark, then ``error_retire``;
* **counter tracks** for the per-block gauges (queue depth, occupancy,
  free slots, live KV bytes, tick horizon K, parked ticks), rendered by
  Perfetto as stepped line charts above the lanes.

Timestamps: events carry engine-clock seconds; the export converts to
microseconds (the trace-event unit). Duration semantics are host-side:
a ``decode_block`` slice spans dispatch -> host sync (real blocking time);
a ``prefill_chunk`` slice spans the batched dispatch call only (the
program itself retires asynchronously), which is the honest host view.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Iterable

PID = 1                      # single engine process
SCHED_TID = 0                # scheduler / pool-ledger lane


def slot_tid(slot: int) -> int:
    """Stable lane id for a slot: tid = slot + 1 (tid 0 is the scheduler)."""
    return int(slot) + 1


def _field(ev, name, default=None):
    """Events may be dataclasses (live stream) or dicts (JSONL reload)."""
    if isinstance(ev, dict):
        return ev.get(name, default)
    return getattr(ev, name, default)


def _us(t: float) -> float:
    return round(float(t) * 1e6, 3)


def _args(ev, **extra) -> dict:
    args = {}
    for k in ("rid", "serial", "block"):
        v = _field(ev, k)
        if v is not None:
            args[k] = v
    data = _field(ev, "data") or {}
    args.update({k: v for k, v in data.items() if k not in extra})
    args.update(extra)
    return args


def chrome_trace(events: Iterable, *, engine_name: str = "serving-engine",
                 ) -> dict:
    """Build the Chrome trace-event dict for an event stream. Deterministic:
    the same stream produces the same JSON, and a slot's tid never changes
    (``tests/test_telemetry.py`` pins both)."""
    out: list[dict] = []
    tids: set[int] = {SCHED_TID}

    def lane(ev) -> int:
        slot = _field(ev, "slot")
        tid = SCHED_TID if slot is None else slot_tid(slot)
        tids.add(tid)
        return tid

    for ev in events:
        kind = _field(ev, "kind")
        t = float(_field(ev, "t"))
        data = _field(ev, "data") or {}
        if kind == "gauges":
            for name, val in data.items():
                if isinstance(val, (int, float)):
                    out.append({"name": name, "ph": "C", "ts": _us(t),
                                "pid": PID, "args": {name: val}})
            continue
        if kind == "decode_block":
            dur = float(data.get("dur", 0.0))
            slots = data.get("slots", [])
            serials = data.get("serials", [None] * len(slots))
            toks = data.get("tokens_per_slot", [None] * len(slots))
            for s, serial, n in zip(slots, serials, toks):
                tids.add(slot_tid(s))
                out.append({
                    "name": f"decode_block k={data.get('k')}",
                    "ph": "X", "ts": _us(t - dur), "dur": _us(dur),
                    "pid": PID, "tid": slot_tid(s),
                    "args": {"rid": None, "serial": serial,
                             "block": _field(ev, "block"),
                             "k": data.get("k"), "tokens": n,
                             "parked_block": data.get("parked")}})
            continue
        if kind == "prefill_chunk":
            dur = float(data.get("dur", 0.0))
            out.append({
                "name": "prefill_chunk", "ph": "X",
                "ts": _us(t - dur), "dur": _us(dur),
                "pid": PID, "tid": lane(ev),
                "args": _args(ev)})
            continue
        # everything else: an instant mark on its lane
        out.append({"name": kind, "ph": "i", "ts": _us(t), "pid": PID,
                    "tid": lane(ev), "s": "t", "args": _args(ev)})

    meta = [{"name": "process_name", "ph": "M", "pid": PID,
             "args": {"name": engine_name}}]
    for tid in sorted(tids):
        name = "scheduler" if tid == SCHED_TID else f"slot {tid - 1}"
        meta.append({"name": "thread_name", "ph": "M", "pid": PID,
                     "tid": tid, "args": {"name": name}})
    return {"traceEvents": meta + out, "displayTimeUnit": "ms",
            "otherData": {"generator": "repro.serving.trace"}}


def write_chrome_trace(events: Iterable, path: str | Path, **kw) -> Path:
    path = Path(path)
    path.write_text(json.dumps(chrome_trace(events, **kw)))
    return path
