from .step import make_train_step
from .loop import TrainLoop

__all__ = ["make_train_step", "TrainLoop"]
