"""Training step: value_and_grad + AdamW, with microbatched gradient
accumulation (activation-memory control for the big dry-run cells — the
global batch splits into ``microbatches`` sequential chunks, grads average)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.api import lm_loss
from repro.optim import adamw_update, cosine_schedule


def _constrain(tree, spec_tree):
    """Pin a param-shaped pytree to the params' PartitionSpecs (keeps the
    grad-accumulation carry FSDP-sharded instead of letting XLA replicate
    tens of GB of f32 gradients). No-op when spec_tree is None or outside a
    mesh context."""
    if spec_tree is None:
        return tree
    try:
        return jax.tree.map(
            lambda x, s: jax.lax.with_sharding_constraint(x, s), tree,
            spec_tree)
    except Exception:
        return tree


def make_train_step(model, *, microbatches: int = 1, base_lr: float = 3e-4,
                    warmup: int = 100, total_steps: int = 10_000,
                    weight_decay: float = 0.1, remat: bool = True,
                    param_specs=None, bf16_gather: bool = False):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics). ``batch``: {tokens, labels[, source]} with global-batch leading.
    ``param_specs``: optional PartitionSpec pytree matching params — applied
    to gradients/accumulators so they shard with the params (FSDP).
    ``bf16_gather``: cast f32 master params to the compute dtype while still
    FSDP-sharded, so the per-layer all-gathers move bf16 instead of f32 —
    halves FSDP collective traffic (beyond-paper perf lever, §Perf)."""

    cdt = jnp.dtype(model.cfg.compute_dtype)

    def loss_fn(params, batch):
        if bf16_gather:
            params = _constrain(
                jax.tree.map(
                    lambda p: p.astype(cdt) if (p.dtype == jnp.float32
                                                and p.ndim >= 2) else p,
                    params),
                param_specs)
        return lm_loss(model, params, batch["tokens"], batch["labels"],
                       batch.get("source"), remat=remat)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            grads = _constrain(grads, param_specs)
        else:
            def split(x):
                return x.reshape(microbatches, x.shape[0] // microbatches,
                                 *x.shape[1:])
            mb = jax.tree.map(split, batch)

            def acc(carry, one):
                loss_acc, grads_acc = carry
                loss, grads = jax.value_and_grad(loss_fn)(params, one)
                grads_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), grads_acc, grads)
                return (loss_acc + loss,
                        _constrain(grads_acc, param_specs)), None

            zeros = _constrain(
                jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params), param_specs)
            (loss, grads), _ = jax.lax.scan(acc, (jnp.zeros((), jnp.float32),
                                                  zeros), mb)
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)

        lr = cosine_schedule(opt_state.step, base_lr=base_lr, warmup=warmup,
                             total=total_steps)
        params, opt_state, metrics = adamw_update(
            params, grads, opt_state, lr=lr, weight_decay=weight_decay)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step
