"""Training loop with fault tolerance: periodic atomic checkpoints,
resume-from-latest on (re)start, bounded step retries on transient failure.

At cluster scale the same loop runs per-controller: a preempted job restarts,
``CheckpointManager.latest_step()`` finds the last valid snapshot, and the
counted data pipeline regenerates the exact step stream. ``failure_injector``
lets tests exercise the recovery path deterministically."""
from __future__ import annotations

import logging
import time
from typing import Callable

import jax

from repro.checkpoint import CheckpointManager
from repro.data.pipeline import batch_for_step, source_for_step
from repro.models.api import needs_source
from repro.optim import adamw_init

log = logging.getLogger("repro.train")


class TrainLoop:
    def __init__(self, model, cfg, train_step: Callable, *, seq_len: int,
                 global_batch: int, ckpt_dir: str, ckpt_every: int = 50,
                 seed: int = 0, max_retries: int = 3,
                 failure_injector: Callable[[int], None] | None = None):
        self.model, self.cfg = model, cfg
        self.train_step = jax.jit(train_step, donate_argnums=(0, 1))
        self.seq_len, self.global_batch = seq_len, global_batch
        self.seed = seed
        self.ckpt = CheckpointManager(ckpt_dir)
        self.ckpt_every = ckpt_every
        self.max_retries = max_retries
        self.failure_injector = failure_injector

    def _batch(self, step: int) -> dict:
        b = batch_for_step(self.cfg.vocab_size, self.seq_len,
                           self.global_batch, self.seed, step)
        if needs_source(self.cfg):
            b["source"] = source_for_step(self.cfg, self.global_batch,
                                          self.seed, step)
        return b

    def init_or_resume(self, rng):
        params = self.model.init_params(rng)
        opt_state = adamw_init(params)
        start = 0
        latest = self.ckpt.latest_step()
        if latest is not None:
            (params, opt_state), start, _ = self.ckpt.restore(
                (params, opt_state), latest)
            log.info("resumed from checkpoint step %d", start)
        return params, opt_state, start

    def run(self, steps: int, rng=None) -> list[dict]:
        rng = jax.random.PRNGKey(self.seed) if rng is None else rng
        params, opt_state, start = self.init_or_resume(rng)
        history = []
        step = start
        while step < steps:
            retries = 0
            while True:
                try:
                    if self.failure_injector is not None:
                        self.failure_injector(step)
                    t0 = time.perf_counter()
                    params, opt_state, metrics = self.train_step(
                        params, opt_state, self._batch(step))
                    metrics = {k: float(v) for k, v in metrics.items()}
                    metrics["step_time_s"] = time.perf_counter() - t0
                    break
                except Exception as e:  # transient failure -> restore + retry
                    retries += 1
                    log.warning("step %d failed (%s); retry %d/%d", step, e,
                                retries, self.max_retries)
                    if retries > self.max_retries:
                        raise
                    latest = self.ckpt.latest_step()
                    if latest is not None:
                        (params, opt_state), step, _ = self.ckpt.restore(
                            jax.tree.map(lambda x: x, (params, opt_state)),
                            latest)
                    else:  # restart from scratch deterministically
                        params, opt_state, step = (*self.init_or_resume(rng)[:2],
                                                   0)
            metrics["step"] = step
            history.append(metrics)
            step += 1
            if step % self.ckpt_every == 0 or step == steps:
                self.ckpt.save(step, (params, opt_state),
                               extra={"seq_len": self.seq_len})
        self._final = (params, opt_state)
        return history
