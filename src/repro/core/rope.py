"""Rotary positional embedding: direct form + the paper's decoder-specialized
incremental recurrence (Eq. 11).

The FPGA cannot afford cos/sin of large angles (CORDIC range limits), so the
paper caches ``(cos m*theta_i, sin m*theta_i)`` and advances one position with
the angle-addition constants ``(a_i, b_i) = (cos theta_i, sin theta_i)`` — four
multiplies per channel pair. We carry exactly that state in the serving loop
(``RopeState``), and since cached keys are stored *post-RoPE* (as in the paper)
only the new token's q/k are ever rotated.

Pairing convention: half-split ("NeoX"/llama style) — channel i pairs with
channel i + d/2. The paper's Eq. 3 uses consecutive pairs; the two are
permutations of each other and produce identical attention as long as q and k
use the same convention (noted in DESIGN.md §6).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


def rope_freqs(head_dim: int, base: float = 10000.0,
               rotary_dim: int | None = None) -> jax.Array:
    """Angular frequencies omega_i (Eq. 1). ``rotary_dim`` < head_dim applies
    RoPE to a prefix of channels only (partial rotary, e.g. ChatGLM)."""
    rd = head_dim if rotary_dim is None else rotary_dim
    i = jnp.arange(rd // 2, dtype=jnp.float32)
    return base ** (-2.0 * i / rd)


def apply_rope(x: jax.Array, positions: jax.Array, base: float = 10000.0,
               rotary_dim: int | None = None) -> jax.Array:
    """Direct RoPE. x: [..., S, D]; positions: [S] or broadcastable [..., S]."""
    d = x.shape[-1]
    rd = d if rotary_dim is None else rotary_dim
    freqs = rope_freqs(d, base, rotary_dim)                      # [rd/2]
    ang = positions[..., None].astype(jnp.float32) * freqs       # [..., S, rd/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x_rot, x_pass = x[..., :rd], x[..., rd:]
    x1, x2 = x_rot[..., : rd // 2], x_rot[..., rd // 2:]
    r1 = x1 * cos - x2 * sin
    r2 = x1 * sin + x2 * cos
    return jnp.concatenate([r1, r2, x_pass], axis=-1).astype(x.dtype)


class RopeState(NamedTuple):
    """Cached (cos m*theta, sin m*theta) for the *current* position m, plus the
    per-step rotation constants (a, b) = (cos theta, sin theta)."""
    cos_m: jax.Array  # [rd/2] f32
    sin_m: jax.Array  # [rd/2] f32
    a: jax.Array      # [rd/2] f32, cos(theta_i)
    b: jax.Array      # [rd/2] f32, sin(theta_i)


def rope_state_init(head_dim: int, base: float = 10000.0,
                    position: int | jax.Array = 0,
                    rotary_dim: int | None = None) -> RopeState:
    freqs = rope_freqs(head_dim, base, rotary_dim)
    m = jnp.asarray(position, jnp.float32)
    return RopeState(
        cos_m=jnp.cos(m * freqs), sin_m=jnp.sin(m * freqs),
        a=jnp.cos(freqs), b=jnp.sin(freqs),
    )


def rope_state_advance(state: RopeState) -> RopeState:
    """Angle addition: cos((m+1)t) = cos(mt)cos(t) - sin(mt)sin(t), etc.
    Four multiplies per channel pair — Eq. 11's datapath."""
    cos_next = state.cos_m * state.a - state.sin_m * state.b
    sin_next = state.sin_m * state.a + state.cos_m * state.b
    return RopeState(cos_m=cos_next, sin_m=sin_next, a=state.a, b=state.b)


def apply_rope_from_state(x: jax.Array, state: RopeState) -> jax.Array:
    """Rotate a single-position vector using the cached angle state.
    x: [..., D] (one token)."""
    d = x.shape[-1]
    rd = 2 * state.cos_m.shape[-1]
    x_rot, x_pass = x[..., :rd], x[..., rd:]
    x1, x2 = x_rot[..., : rd // 2], x_rot[..., rd // 2:]
    r1 = x1 * state.cos_m - x2 * state.sin_m
    r2 = x1 * state.sin_m + x2 * state.cos_m
    return jnp.concatenate([r1, r2, x_pass], axis=-1).astype(x.dtype)
