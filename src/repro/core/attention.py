"""Batched multi-head attention built on the SwiftKV primitives.

Two entry points used by every model:

  * ``decode_attention``  — one new token against a KV cache (the paper's
    target workload). GQA/MQA-aware; dispatches between the paper-faithful
    tokenwise scan, the blockwise TPU form, and the Pallas kernel.
  * ``prefill_attention`` — multi-token self/cross attention as a *single-pass
    blockwise* scan over KV blocks using the same ``(mu, Z, Y)`` recurrence
    (flash-style, no S x S score materialization), so 32k-token prefill lowers
    with O(S·D) live memory.

Layouts: activations ``[B, S, H, D]``; KV caches ``[B, S, Hkv, D]``.
"""
from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

from . import swiftkv
from .swiftkv import NEG_INF, SwiftKVState, state_init, state_update_block, state_finalize

DecodeImpl = Literal["tokenwise", "blockwise", "kernel", "naive", "sp"]


# ---------------------------------------------------------------------------
# Decode (one query token per sequence)
# ---------------------------------------------------------------------------

def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     lengths: jax.Array, *, impl: DecodeImpl = "blockwise",
                     window: int | None = None, ring: bool = False,
                     block_size: int = 512,
                     scale: float | None = None,
                     k_scale: jax.Array | None = None,
                     v_scale: jax.Array | None = None) -> jax.Array:
    """q: [B, Hq, D]; k_cache/v_cache: [B, S, Hkv, D]; lengths: [B] int32.
    Returns [B, Hq, D]. Hq must be a multiple of Hkv (GQA groups).

    ``ring=True``: the cache is a ring of R = S slots (SWA configs —
    ``window`` required); ``lengths`` counts tokens seen and may exceed S
    once wrapped. The blockwise and kernel paths consume the ring *in
    place* — per-slot absolute positions are recovered arithmetically, so
    there is no unrotate copy and the single-pass exactly-once contract
    holds on the wrapped layout. ``tokenwise`` / ``sp`` have no ring form
    and fall back to blockwise; ``naive`` uses the dense ring oracle.

    The blockwise path's KV loop is length-adaptive (see
    ``swiftkv_decode_blockwise``): under the vmap below each batch row runs
    ``cdiv(length, block)`` block steps, so a big preallocated cache costs
    attention work proportional to the longest *active* sequence — not to
    ``S`` — on every decode tick.

    ``k_scale`` / ``v_scale``: optional [B, Hkv, S] float (f32/bf16) per-(row, head,
    position) dequant scales for an **int8 KV cache** (the ``+w4a8``
    serving form, ``quantization.quantize_kv``). The scale multiply rides
    the blockwise/kernel block loads — no dequantized copy of the cache is
    materialized. ``tokenwise`` / ``sp`` have no int8 form and fall back to
    blockwise; ``naive`` dequantizes up front (it is the dense oracle)."""
    b, hq, d = q.shape
    hkv = k_cache.shape[2]
    assert hq % hkv == 0, (hq, hkv)
    g = hq // hkv

    if k_scale is not None:
        if impl in ("sp", "tokenwise"):
            impl = "blockwise"   # no seq-sharded / per-token int8 form
        if impl == "naive":
            # dense oracle: dequantize whole (small, test-sized) caches
            sc = jnp.swapaxes(k_scale, 1, 2)[..., None]   # [B, S, Hkv, 1]
            return decode_attention(
                q, k_cache.astype(jnp.float32) * sc,
                v_cache.astype(jnp.float32) * jnp.swapaxes(
                    v_scale, 1, 2)[..., None],
                lengths, impl="naive", window=window, ring=ring,
                block_size=block_size, scale=scale)

    if ring:
        if window is None:
            raise ValueError("ring caches are windowed: pass window")
        if impl in ("sp", "tokenwise"):
            impl = "blockwise"   # no seq-sharded / per-token ring form
        if impl == "naive":
            return decode_attention_ring(q, k_cache, v_cache, lengths,
                                         window=window, scale=scale)

    if impl == "sp":
        # sequence-parallel monoid-merge decode: the KV cache stays
        # seq-sharded over the model axis; each shard folds its slice with
        # the single-pass recurrence and the partial (mu, Z, Y) triples merge
        # with one tiny collective (exact — DESIGN.md §2). Falls back to
        # blockwise outside a mesh context or on non-divisible caches.
        from repro.distributed.context import get_context
        ctx = get_context()
        s_len = k_cache.shape[1]
        if (ctx.active and ctx.model_axis is not None
                and s_len % ctx.axis_size(ctx.model_axis) == 0):
            from repro.distributed.sp_attention import decode_attention_sp
            return decode_attention_sp(
                q, k_cache, v_cache, lengths, mesh=ctx.mesh,
                seq_axes=ctx.model_axis, window=window,
                block_size=min(block_size,
                               s_len // ctx.axis_size(ctx.model_axis)),
                scale=scale)
        impl = "blockwise"

    if impl == "kernel":
        from repro.kernels.swiftkv_decode import ops as kops
        return kops.swiftkv_decode(q, k_cache, v_cache, lengths,
                                   window=window, ring=ring,
                                   block_k=block_size, scale=scale,
                                   k_scale=k_scale, v_scale=v_scale)

    # group queries: [B, Hkv, G, D]; caches to [B, Hkv, S, D]
    qg = q.reshape(b, hkv, g, d)
    kc = jnp.swapaxes(k_cache, 1, 2)
    vc = jnp.swapaxes(v_cache, 1, 2)

    if impl == "tokenwise":
        fn = functools.partial(swiftkv.swiftkv_decode_tokenwise, scale=scale)
        if window is not None:
            raise NotImplementedError("tokenwise path: use blockwise for SWA")
    elif impl == "blockwise":
        fn = functools.partial(swiftkv.swiftkv_decode_blockwise, scale=scale,
                               window=window, ring=ring,
                               block_size=block_size)
    elif impl == "naive":
        fn = functools.partial(swiftkv.softmax_attention_reference, scale=scale,
                               window=window)
    else:
        raise ValueError(impl)

    if k_scale is not None:
        # int8 blockwise: scales ride the same vmap nest, one [S] vector per
        # (row, head) shared across the head group
        per_group = jax.vmap(fn, in_axes=(0, None, None, None, None, None))
        per_head = jax.vmap(per_group, in_axes=(0, 0, 0, None, 0, 0))
        per_batch = jax.vmap(per_head, in_axes=(0, 0, 0, 0, 0, 0))
        out = per_batch(qg, kc, vc, lengths, k_scale, v_scale)
        return out.reshape(b, hq, d)

    # vmap: queries within a group share one KV scan (in_axes k/v None)
    per_group = jax.vmap(fn, in_axes=(0, None, None, None))      # over G
    per_head = jax.vmap(per_group, in_axes=(0, 0, 0, None))      # over Hkv
    per_batch = jax.vmap(per_head, in_axes=(0, 0, 0, 0))         # over B
    out = per_batch(qg, kc, vc, lengths)                          # [B, Hkv, G, D]
    return out.reshape(b, hq, d)


def decode_cross_attention(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                           entries: jax.Array, lengths: jax.Array, *,
                           impl: DecodeImpl = "blockwise",
                           block_size: int = 512,
                           scale: float | None = None,
                           k_scale: jax.Array | None = None,
                           v_scale: jax.Array | None = None) -> jax.Array:
    """Ragged cross-attention decode read over a shared **source-KV pool**.

    q: [B, Hq, D] (one decoder token per slot); k_pool / v_pool:
    [E, S_src, Hkv, D] — E pooled encoder-side entries, NOT batched by slot;
    entries: [B] int32 maps each slot to its pool entry (requests sharing a
    source id share an entry — the :class:`repro.serving.slot_pool.
    SourceKVPool` contract); lengths: [B] int32 per-slot valid source
    prefix. Rows with *different* encoder lengths (and different entries)
    coexist in one static-shape dispatch: each row masks its own tail, a
    ``length == 0`` row (no source / inactive slot) reads an exact zero.

    Non-causal, unwindowed, and read-only — nothing is written back, which
    is what lets the pool be shared. The blockwise path folds the entry
    index into the KV block reads (``swiftkv_decode_pooled``), so no
    per-slot copy of the pool is ever materialized. ``tokenwise`` / ``sp``
    / ``kernel`` have no pooled form and fall back to blockwise; ``naive``
    gathers the per-slot entries and runs the dense oracle.

    ``k_scale`` / ``v_scale``: optional [E, Hkv, S] float (f32/bf16) per-(entry, head,
    position) dequant scales for an int8 source-KV pool — folded into the
    pooled block reads like the self-attention form."""
    b, hq, d = q.shape
    hkv = k_pool.shape[2]
    assert hq % hkv == 0, (hq, hkv)
    g = hq // hkv
    entries = jnp.asarray(entries, jnp.int32)
    lengths = jnp.asarray(lengths, jnp.int32)

    if impl == "naive":
        # dense oracle: gather each slot's entry, then the batched reference
        kc = jnp.take(k_pool, entries, axis=0)           # [B, S, Hkv, D]
        vc = jnp.take(v_pool, entries, axis=0)
        return decode_attention(
            q, kc, vc, lengths, impl="naive", scale=scale,
            k_scale=(None if k_scale is None
                     else jnp.take(k_scale, entries, axis=0)),
            v_scale=(None if v_scale is None
                     else jnp.take(v_scale, entries, axis=0)))

    qg = q.reshape(b, hkv, g, d)
    kp = jnp.swapaxes(k_pool, 1, 2)                      # [E, Hkv, S, D]
    vp = jnp.swapaxes(v_pool, 1, 2)
    fn = functools.partial(swiftkv.swiftkv_decode_pooled,
                           block_size=block_size, scale=scale)
    if k_scale is not None:
        # pooled int8: the [E, S] scale planes broadcast like the pool
        per_group = jax.vmap(fn, in_axes=(0, None, None, None, None,
                                          None, None))             # over G
        per_head = jax.vmap(per_group, in_axes=(0, 1, 1, None, None,
                                                1, 1))             # over Hkv
        per_batch = jax.vmap(per_head, in_axes=(0, None, None, 0, 0,
                                                None, None))       # over B
        out = per_batch(qg, kp, vp, entries, lengths, k_scale, v_scale)
        return out.reshape(b, hq, d)
    # vmap: queries within a group share one pooled scan; the pool itself is
    # broadcast (in_axes None) — only (q, entry, length) are per-row
    per_group = jax.vmap(fn, in_axes=(0, None, None, None, None))  # over G
    per_head = jax.vmap(per_group, in_axes=(0, 1, 1, None, None))  # over Hkv
    per_batch = jax.vmap(per_head, in_axes=(0, None, None, 0, 0))  # over B
    out = per_batch(qg, kp, vp, entries, lengths)        # [B, Hkv, G, D]
    return out.reshape(b, hq, d)


def decode_attention_ring(q: jax.Array, k_cache: jax.Array,
                          v_cache: jax.Array, lengths: jax.Array, *,
                          window: int, scale: float | None = None) -> jax.Array:
    """Sliding-window decode over a RING KV cache (beyond-paper).

    q: [B, Hq, D]; k/v_cache: [B, R, Hkv, D] with R >= window+1 ring slots;
    ``lengths``: tokens seen so far (the newest token lives at slot
    (lengths-1) % R). Slot s holds absolute position p - ((p - s) mod R)
    where p = lengths-1; a slot is attended iff its position is in
    [lengths-window, lengths). R is ~window, independent of context, so a
    500k-token decode reads ~window KV entries per step — the exactly-once
    property with an O(window) working set."""
    b, hq, d = q.shape
    r, hkv = k_cache.shape[1], k_cache.shape[2]
    g = hq // hkv
    scale = (1.0 / d ** 0.5) if scale is None else scale

    p = (lengths - 1)[:, None]                            # [B, 1]
    s = jnp.arange(r)[None, :]                            # [1, R]
    pos = p - jnp.mod(p - s, r)                           # [B, R] absolute
    valid = (pos >= 0) & (pos > p - window)               # in-window slots

    qg = q.reshape(b, hkv, g, d).astype(jnp.float32)
    kc = k_cache.astype(jnp.float32)
    vc = v_cache.astype(jnp.float32)
    sc = jnp.einsum("bhgd,bshd->bhgs", qg, kc) * scale    # [B,Hkv,G,R]
    sc = jnp.where(valid[:, None, None, :], sc, NEG_INF)
    pr = jax.nn.softmax(sc, axis=-1)
    pr = jnp.where(valid[:, None, None, :], pr, 0.0)
    out = jnp.einsum("bhgs,bshd->bhgd", pr, vc)
    return out.reshape(b, hq, d).astype(q.dtype)


def prefill_attention_ring(q: jax.Array, k_ring: jax.Array, v_ring: jax.Array,
                           q_positions: jax.Array, p_max: jax.Array, *,
                           window: int, scale: float | None = None) -> jax.Array:
    """Causal SWA attention of a prompt *chunk* over a RING KV cache.

    q: [B, C, Hq, D] — chunk queries at absolute positions ``q_positions``
    [C]; k/v_ring: [B, R, Hkv, D] ring caches that already contain this
    chunk's keys (written at ``pos % R``) on top of the slot's history;
    ``p_max``: the last *real* (non-padding) position written. Slot ``s``
    holds absolute position ``p_max - ((p_max - s) mod R)``; a slot is
    attended by query row ``c`` iff that position is in
    ``(q_positions[c] - window, q_positions[c]]`` — which also masks (a)
    slots a later in-chunk token overwrote (their lost position is provably
    out of the earlier query's window when R >= window + C - 1, the
    engine-enforced ring slack), (b) a previous occupant's stale slots
    (their recovered position is negative until this request wraps), and
    (c) padded tail rows (never written: ``keep``-masked by the caller).

    C and R are both small (a prefill chunk against ~window ring slots), so
    this materializes the [C, R] score block directly — the chunk analogue
    of the dense ring decode oracle, not a streamed pass."""
    b, c, hq, d = q.shape
    r, hkv = k_ring.shape[1], k_ring.shape[2]
    g = hq // hkv
    scale = (1.0 / d ** 0.5) if scale is None else scale

    s_idx = jnp.arange(r)[None, :]                        # [1, R]
    pos = p_max - jnp.mod(p_max - s_idx, r)               # [1, R] absolute
    qp = q_positions[:, None]                             # [C, 1]
    valid = (pos >= 0) & (pos <= qp) & (pos > qp - window)  # [C, R]

    qg = q.reshape(b, c, hkv, g, d).astype(jnp.float32)
    kc = k_ring.astype(jnp.float32)
    vc = v_ring.astype(jnp.float32)
    sc = jnp.einsum("bchgd,brhd->bchgr", qg, kc) * scale  # [B,C,Hkv,G,R]
    sc = jnp.where(valid[None, :, None, None, :], sc, NEG_INF)
    pr = jax.nn.softmax(sc, axis=-1)
    pr = jnp.where(valid[None, :, None, None, :], pr, 0.0)
    out = jnp.einsum("bchgr,brhd->bchgd", pr, vc)
    return out.reshape(b, c, hq, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# Prefill (blockwise single-pass over KV; SwiftKV state per query row)
# ---------------------------------------------------------------------------

def _heads_constrain(x: jax.Array):
    """Pin [B, H, ...] activations to (batch over DP axes, heads over the
    model axis) — the TPU analogue of the paper's one-head-per-processor
    layout. Without it the reshape chain around GQA grouping loses the head
    sharding and every chip materializes all-head score tensors."""
    from repro.distributed.context import get_context
    ctx = get_context()
    if not ctx.active:
        return x
    bd = ctx.batch_axes if x.shape[0] % ctx.axis_size(ctx.batch_axes) == 0 \
        else None
    h_ax = ctx.model_axis if x.shape[1] % ctx.axis_size(ctx.model_axis) == 0 \
        else None
    try:
        from jax.sharding import PartitionSpec as P
        return jax.lax.with_sharding_constraint(
            x, P(bd, h_ax, *([None] * (x.ndim - 2))))
    except Exception:
        return x


def prefill_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, window: int | None = None,
                      kv_lengths: jax.Array | None = None,
                      q_offset: jax.Array | None = None,
                      kv_block: int = 512, scale: float | None = None) -> jax.Array:
    """q: [B, Sq, Hq, D]; k, v: [B, Skv, Hkv, D] -> [B, Sq, Hq, D].

    Single pass over KV blocks with the SwiftKV ``(mu, Z, Y)`` state per
    query row (flash-style; no Sq x Skv score materialization). GQA KV heads
    are repeated to the full query-head count so the head axis stays
    TP-shardable (Hkv < TP cannot be expressed through the grouped layout);
    each KV-block step is rematted, so backward recomputes scores blockwise
    instead of saving every block's score tensor.

    ``kv_lengths``: [B] valid KV prefix (cross-attention padding / appended
    decode). ``q_offset``: [B] absolute position of q row 0 (0 for prefill)."""
    b, sq, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = (1.0 / (d ** 0.5)) if scale is None else scale
    kv_lengths = jnp.full((b,), skv, jnp.int32) if kv_lengths is None else kv_lengths
    q_offset = jnp.zeros((b,), jnp.int32) if q_offset is None else q_offset

    qh = _heads_constrain(jnp.swapaxes(q, 1, 2))       # [B, Hq, Sq, D]
    kh = jnp.swapaxes(k, 1, 2)                          # [B, Hkv, Skv, D]
    vh = jnp.swapaxes(v, 1, 2)
    if g > 1:                                           # repeat KV to q heads
        kh = jnp.repeat(kh, g, axis=1)
        vh = jnp.repeat(vh, g, axis=1)
    kh = _heads_constrain(kh)
    vh = _heads_constrain(vh)

    n_blocks = -(-skv // kv_block)
    pad = n_blocks * kv_block - skv
    if pad:
        kh = jnp.pad(kh, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vh = jnp.pad(vh, ((0, 0), (0, 0), (0, pad), (0, 0)))

    qf = qh.astype(jnp.float32) * scale
    pos_q = q_offset[:, None] + jnp.arange(sq)[None]    # [B, Sq]

    def step(state, j):
        k_blk = jax.lax.dynamic_slice_in_dim(kh, j * kv_block, kv_block,
                                             axis=2).astype(jnp.float32)
        v_blk = jax.lax.dynamic_slice_in_dim(vh, j * kv_block, kv_block,
                                             axis=2).astype(jnp.float32)
        pos_k = j * kv_block + jnp.arange(kv_block)     # [Bk]
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, k_blk)    # [B, H, Sq, Bk]
        valid = pos_k[None, None, :] < kv_lengths[:, None, None]  # [B, 1, Bk]
        valid = jnp.broadcast_to(valid, (b, sq, kv_block))
        if causal:
            valid &= pos_k[None, None, :] <= pos_q[:, :, None]
        if window is not None:
            valid &= pos_k[None, None, :] > pos_q[:, :, None] - window
        valid = valid[:, None]                           # [B, 1, Sq, Bk]
        s = jnp.where(valid, s, NEG_INF)
        mu, z, y = state
        mu_blk = jnp.max(s, axis=-1)
        mu_new = jnp.maximum(mu, mu_blk)
        alpha = jnp.exp(mu - mu_new)
        p = jnp.exp(s - mu_new[..., None]) * valid       # [B, H, Sq, Bk]
        z_new = alpha * z + jnp.sum(p, axis=-1)
        y_new = (alpha[..., None] * y
                 + jnp.einsum("bhqk,bhkd->bhqd", p, v_blk))
        return SwiftKVState(mu=mu_new, z=z_new, y=y_new), None

    init = state_init(d, batch_shape=(b, hq, sq))
    # remat each block step: backward recomputes the [B,H,Sq,Bk] scores
    # per block instead of saving n_blocks of them
    state, _ = jax.lax.scan(jax.checkpoint(step), init,
                            jnp.arange(n_blocks))
    out = state_finalize(state).astype(q.dtype)          # [B, Hq, Sq, D]
    return jnp.swapaxes(out, 1, 2)
