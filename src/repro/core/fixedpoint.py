"""FXP32 Q15.17 fixed-point emulation (paper §III).

The FPGA computes all of SwiftKV attention in 32-bit fixed point, Q15.17
(15 integer bits, 17 fractional, 1 sign), claiming end-to-end attention
precision better than 1e-5. TPUs have no fixed-point datapath, so this module
is a *bit-accurate numpy emulation* used to validate that claim (and Table I's
Top-k agreement) — the performance path runs bf16/f32 on the MXU (DESIGN.md §2).

numpy int64 holds every intermediate exactly: Q15.17 x Q15.17 products are
<= 62 bits before the renormalizing shift.
"""
from __future__ import annotations

import numpy as np

from .exp2_lut import exp_lut_fxp, FRAC_BITS

ONE = 1 << FRAC_BITS
_INT32_MIN = -(1 << 31)
_INT32_MAX = (1 << 31) - 1


def to_fxp(x: np.ndarray) -> np.ndarray:
    """float -> Q15.17 (round-to-nearest, saturating like the hardware)."""
    q = np.round(np.asarray(x, np.float64) * ONE)
    return np.clip(q, _INT32_MIN, _INT32_MAX).astype(np.int64)


def from_fxp(x: np.ndarray) -> np.ndarray:
    return np.asarray(x, np.float64) / ONE


def fxp_mul(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Q15.17 multiply: 64-bit product, round-to-nearest shift right 17,
    saturate to 32 bits."""
    p = np.asarray(a, np.int64) * np.asarray(b, np.int64)
    p = (p + (1 << (FRAC_BITS - 1))) >> FRAC_BITS
    return np.clip(p, _INT32_MIN, _INT32_MAX)


def fxp_div(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Q15.17 divide: (a << 17) / b with truncation."""
    num = np.asarray(a, np.int64) << FRAC_BITS
    b = np.asarray(b, np.int64)
    b_safe = np.where(b == 0, 1, b)
    # round-to-nearest division (hardware divider with rounding stage)
    half = np.abs(b_safe) >> 1
    q = (num + np.where((num < 0) != (b_safe < 0), -half, half)) // b_safe
    return np.clip(np.where(b == 0, 0, q), _INT32_MIN, _INT32_MAX)


def fxp_dot(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Dot product along the last axis with a 64-bit accumulator (the MAC
    array accumulates full products before the final renormalization)."""
    acc = np.sum(np.asarray(a, np.int64) * np.asarray(b, np.int64), axis=-1)
    acc = (acc + (1 << (FRAC_BITS - 1))) >> FRAC_BITS
    return np.clip(acc, _INT32_MIN, _INT32_MAX)


def swiftkv_attention_fxp(q: np.ndarray, k: np.ndarray, v: np.ndarray,
                          scale: float | None = None) -> np.ndarray:
    """The full SwiftKV recurrence (Eqs. 5-8) in Q15.17 with the Eq. 9-10 LUT
    exponential — the paper's datapath end to end.

    q: [D] float; k, v: [S, D] float. Returns float64 attention output.
    """
    d = q.shape[-1]
    scale = 1.0 / np.sqrt(d) if scale is None else scale
    scale_fxp = to_fxp(scale)
    qf = to_fxp(q)
    kf = to_fxp(k)
    vf = to_fxp(v)
    s_all = fxp_mul(fxp_dot(qf[None, :], kf), scale_fxp)   # Eq. 5, [S]

    mu = s_all[0]
    z = ONE                       # Z_1 = 1.0
    y = vf[0].astype(np.int64)    # Y_1 = v_1
    for t in range(1, k.shape[0]):
        s_t = s_all[t]
        if s_t <= mu:                                      # Eq. 6
            beta = exp_lut_fxp(s_t - mu)
            z = z + beta
            y = y + fxp_mul(beta, vf[t])
        else:                                              # Eq. 7
            alpha = exp_lut_fxp(mu - s_t)
            z = fxp_mul(alpha, z) + ONE
            y = fxp_mul(alpha, y) + vf[t]
            mu = s_t
        z = int(np.clip(z, _INT32_MIN, _INT32_MAX))
        y = np.clip(y, _INT32_MIN, _INT32_MAX)
    out = fxp_div(y, z)                                    # Eq. 8
    return from_fxp(out)
