"""Core SwiftKV algorithms (paper Eqs. 5-11) and supporting numerics."""
from . import attention, exp2_lut, fixedpoint, quantization, rope, swiftkv
from .swiftkv import (NEG_INF, SwiftKVState, softmax_attention_reference,
                      state_finalize, state_init, state_merge,
                      state_update_block, swiftkv_decode_blockwise,
                      swiftkv_decode_tokenwise)

__all__ = [
    "attention", "exp2_lut", "fixedpoint", "quantization", "rope", "swiftkv",
    "NEG_INF", "SwiftKVState", "softmax_attention_reference", "state_finalize",
    "state_init", "state_merge", "state_update_block",
    "swiftkv_decode_blockwise", "swiftkv_decode_tokenwise",
]
