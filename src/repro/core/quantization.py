"""W4A8 quantization (paper §IV-B): INT4 weights x INT8 activations -> INT32
partial sums, rescaled to higher precision between ops.

Weights: symmetric *group-wise* int4 in [-8, 7] — one f32 scale per
(128-input-channel group, output channel) — packed two nibbles per uint8
along the output axis. Group-wise scales are what make int4 weights hit the
paper's Table-I token agreement; plain per-channel int4 loses ~14% relative
error on d~1k matmuls, group-128 gets ~3-4%. Activations: symmetric per-token
dynamic int8. The Pallas kernel (kernels/gemv_w4a8) consumes the packed form;
this module is the quantizer + the pure-jnp reference semantics.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

GROUP = 128  # input channels per quantization group


class QuantizedLinear(NamedTuple):
    """Packed W4 weight for a [K, N] linear layer."""
    packed: jax.Array   # [K, N//2] uint8 — two int4 output-channels per byte
    scale: jax.Array    # [K//GROUP, N] f32 per-(group, out-channel) scale
    bias: jax.Array | None


_CLIP_CANDIDATES = (0.7, 0.8, 0.85, 0.9, 1.0)


def quantize_w4(w: jax.Array, group: int = GROUP) -> QuantizedLinear:
    """w: [K, N] float -> group-wise symmetric int4, packed along N.

    Per-group MSE search over clip factors: pure min-max scaling is
    MSE-suboptimal for bell-shaped weights (~12% rel err on gaussians);
    clipping the range to ~0.85 x amax trades saturation for resolution
    (~10.5%, the RTN-int4 floor)."""
    k, n = w.shape
    assert n % 2 == 0, "output dim must be even to pack nibbles"
    pad_k = (-k) % group
    if pad_k:
        w = jnp.pad(w, ((0, pad_k), (0, 0)))
    kp = w.shape[0]
    wg = w.reshape(kp // group, group, n)
    amax = jnp.max(jnp.abs(wg), axis=1)                   # [K/G, N]

    best_scale, best_err = None, None
    for c in _CLIP_CANDIDATES:
        s = jnp.where(amax > 0, c * amax / 7.0, 1.0).astype(jnp.float32)
        qc = jnp.clip(jnp.round(wg / s[:, None, :]), -8, 7)
        err = jnp.sum((qc * s[:, None, :] - wg) ** 2, axis=1)   # [K/G, N]
        if best_err is None:
            best_scale, best_err = s, err
        else:
            pick = err < best_err
            best_scale = jnp.where(pick, s, best_scale)
            best_err = jnp.minimum(err, best_err)

    scale = best_scale
    q = jnp.clip(jnp.round(wg / scale[:, None, :]), -8, 7)
    q = q.reshape(kp, n)[:k].astype(jnp.int8)
    lo = q[:, 0::2].astype(jnp.uint8) & 0xF
    hi = (q[:, 1::2].astype(jnp.uint8) & 0xF) << 4
    return QuantizedLinear(packed=lo | hi, scale=scale, bias=None)


def unpack_w4(packed: jax.Array) -> jax.Array:
    """[K, N//2] uint8 -> [K, N] int8 in [-8, 7] (sign-extended nibbles)."""
    lo = (packed & 0xF).astype(jnp.int8)
    hi = (packed >> 4).astype(jnp.int8)
    lo = jnp.where(lo >= 8, lo - 16, lo)
    hi = jnp.where(hi >= 8, hi - 16, hi)
    k = packed.shape[0]
    out = jnp.stack([lo, hi], axis=-1).reshape(k, -1)
    return out


def quantize_a8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-token (last-axis) symmetric int8. x: [..., K] -> (q, scale[..., 1])."""
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def w4a8_matmul_ref(x: jax.Array, qw: QuantizedLinear,
                    group: int = GROUP) -> jax.Array:
    """Reference W4A8 linear: quantize activations, int32 accumulate per
    group, group-rescale, sum. x: [..., K] float -> [..., N] float32."""
    xq, xs = quantize_a8(x)
    k = xq.shape[-1]
    n = qw.packed.shape[1] * 2
    pad_k = (-k) % group
    if pad_k:
        xq = jnp.pad(xq, (*[(0, 0)] * (xq.ndim - 1), (0, pad_k)))
    w = unpack_w4(qw.packed)                              # [K, N] int8
    if pad_k:
        w = jnp.pad(w, ((0, pad_k), (0, 0)))
    kp = w.shape[0]
    g = kp // group
    xg = xq.reshape(*xq.shape[:-1], g, group)
    wg = w.reshape(g, group, n)
    acc = jnp.einsum("...gk,gkn->...gn", xg.astype(jnp.int32),
                     wg.astype(jnp.int32))                # [..., G, N] int32
    out = jnp.sum(acc.astype(jnp.float32) * qw.scale, axis=-2) * xs
    if qw.bias is not None:
        out = out + qw.bias
    return out


def quantize_kv(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric int8 over the head dimension (last axis) — the serving KV
    cache's storage form. x: [..., Dh] float -> (q [..., Dh] int8,
    scale [...] f32) with scale = amax / 127 per leading index (one scale
    per (slot, position, kv-head) in the cache layout).

    Properties the test layer pins: a constant vector ``c * ones`` round
    trips *exactly* (scale = |c|/127, q = ±127, dequant = c); an all-zero
    row stores scale 0 (not 1), so a released slot's device state is
    all-zeros — rows and scales both — and gaussian rows round-trip within
    ~1% relative error (int8 is 25x finer than the int4 weight grid)."""
    amax = jnp.max(jnp.abs(x), axis=-1)
    scale = jnp.where(amax > 0, amax / 127.0, 0.0).astype(jnp.float32)
    safe = jnp.where(scale > 0, scale, 1.0)[..., None]
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / safe), -127, 127)
    return q.astype(jnp.int8), scale


def dequantize_kv(q: jax.Array, scale: jax.Array) -> jax.Array:
    """Inverse of :func:`quantize_kv`. q: [..., Dh] int8, scale: [...] f32
    -> [..., Dh] f32."""
    return q.astype(jnp.float32) * scale[..., None]


def dequantize_w4(qw: QuantizedLinear, group: int = GROUP) -> jax.Array:
    w = unpack_w4(qw.packed).astype(jnp.float32)
    k, n = w.shape
    pad_k = (-k) % group
    if pad_k:
        w = jnp.pad(w, ((0, pad_k), (0, 0)))
    wg = w.reshape(-1, group, n) * qw.scale[:, None, :]
    return wg.reshape(-1, n)[:k]
