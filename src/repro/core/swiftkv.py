"""SwiftKV attention — the paper's core algorithm (Eqs. 5-8).

Single-pass, per-token, no-score-materialization decode attention.

Three equivalent realizations, all *exact* (not approximations of softmax):

  * ``swiftkv_decode_tokenwise``   — the paper-faithful per-token ``lax.scan``
    with the literal two-branch update of Eqs. (6)/(7).
  * ``swiftkv_decode_blockwise``   — the TPU adaptation: the same recurrence at
    KV-block granularity (single pass, exactly-once, no second pass).
  * ``SwiftKVState`` monoid        — ``state_update_block`` / ``state_merge`` /
    ``state_finalize``; the merge makes the triple ``(mu, Z, Y)`` an associative
    commutative monoid, enabling cross-device sequence-parallel decode.

Conventions: single-head shapes. ``q: [D]``, ``k/v: [S, D]``. Batch/head axes
are added by ``vmap`` in :mod:`repro.core.attention`.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

# Large-negative stand-in for -inf: exp(NEG_INF - x) underflows to 0 for any
# finite x, while NEG_INF - NEG_INF == 0 stays NaN-free (unlike -inf).
NEG_INF = -1e30


class SwiftKVState(NamedTuple):
    """The paper's running triple. ``mu``: running max score, ``z``: running
    normalizer, ``y``: running unnormalized output. Leading dims are free."""

    mu: jax.Array  # [...]
    z: jax.Array   # [...]
    y: jax.Array   # [..., D]


def state_init(head_dim: int, dtype=jnp.float32, batch_shape=()) -> SwiftKVState:
    return SwiftKVState(
        mu=jnp.full(batch_shape, NEG_INF, dtype=dtype),
        z=jnp.zeros(batch_shape, dtype=dtype),
        y=jnp.zeros((*batch_shape, head_dim), dtype=dtype),
    )


# ---------------------------------------------------------------------------
# Paper-faithful per-token recurrence (Eqs. 5-8)
# ---------------------------------------------------------------------------

def _token_update_branchy(state: SwiftKVState, s_t: jax.Array, v_t: jax.Array,
                          valid: jax.Array) -> SwiftKVState:
    """Literal Eqs. (6)/(7): two branches selected by ``s_t <= mu``.

    ``valid`` masks padded cache slots (the FPGA streams exactly ``T`` pairs;
    our fixed-shape caches carry a length mask instead).
    """
    mu, z, y = state
    le = s_t <= mu
    # branch (6): s_t <= mu          # branch (7): s_t > mu
    beta = jnp.exp(s_t - mu)         # alpha = exp(mu - s_t)
    alpha = jnp.exp(mu - s_t)
    z_le = z + beta
    y_le = y + beta * v_t
    z_gt = alpha * z + 1.0
    y_gt = alpha * y + v_t
    mu_new = jnp.where(le, mu, s_t)
    z_new = jnp.where(le, z_le, z_gt)
    y_new = jnp.where(le, y_le, y_gt)
    # masked token: state passes through unchanged
    return SwiftKVState(
        mu=jnp.where(valid, mu_new, mu),
        z=jnp.where(valid, z_new, z),
        y=jnp.where(valid, y_new, y),
    )


def _token_update_fused(state: SwiftKVState, s_t: jax.Array, v_t: jax.Array,
                        valid: jax.Array) -> SwiftKVState:
    """Branch-free rewrite of Eqs. (6)/(7): with ``mu' = max(mu, s_t)`` both
    branches become ``z' = e^{mu-mu'} z + e^{s_t-mu'}``; exponent arguments stay
    in (-inf, 0] exactly as the paper requires for its hardware exp."""
    mu, z, y = state
    s_eff = jnp.where(valid, s_t, NEG_INF)
    mu_new = jnp.maximum(mu, s_eff)
    alpha = jnp.exp(mu - mu_new)            # in (0, 1]
    beta = jnp.exp(s_eff - mu_new) * valid  # in (0, 1]; 0 on masked lanes
    return SwiftKVState(mu=mu_new, z=alpha * z + beta, y=alpha * y[...] + beta * v_t)


def swiftkv_decode_tokenwise(q: jax.Array, k: jax.Array, v: jax.Array,
                             length: jax.Array | None = None,
                             *, branchy: bool = True,
                             scale: float | None = None) -> jax.Array:
    """Paper-faithful SwiftKV decode attention: scan the KV cache exactly once,
    one ``(k_t, v_t)`` per step, then one deferred normalization (Eq. 8).

    q: [D]; k, v: [S, D]; length: scalar int (valid prefix of the cache).
    """
    d = q.shape[-1]
    s_cache = k.shape[0]
    scale = (1.0 / jnp.sqrt(d)) if scale is None else scale
    length = jnp.asarray(s_cache if length is None else length)
    update = _token_update_branchy if branchy else _token_update_fused

    def step(state, inputs):
        k_t, v_t, t = inputs
        s_t = jnp.dot(q.astype(jnp.float32), k_t.astype(jnp.float32)) * scale  # Eq. 5
        return update(state, s_t, v_t.astype(jnp.float32), (t < length).astype(jnp.float32)), None

    init = state_init(v.shape[-1])
    state, _ = jax.lax.scan(step, init, (k, v, jnp.arange(s_cache)))
    return state_finalize(state).astype(q.dtype)


# ---------------------------------------------------------------------------
# Blockwise single-pass (TPU-granularity) update + monoid merge
# ---------------------------------------------------------------------------

def state_update_block(state: SwiftKVState, s_blk: jax.Array, v_blk: jax.Array,
                       valid_blk: jax.Array) -> SwiftKVState:
    """Consume one KV block. ``s_blk: [..., Bk]`` pre-scaled scores,
    ``v_blk: [..., Bk, D]``, ``valid_blk: [..., Bk]`` float mask.

    Exactly the paper's recurrence applied to a block of tokens at once: the
    block max plays the role of the incoming score; every (k_t, v_t) is still
    consumed exactly once and no score matrix is ever materialized globally.
    """
    mu, z, y = state
    s_eff = jnp.where(valid_blk > 0, s_blk, NEG_INF)
    mu_blk = jnp.max(s_eff, axis=-1)
    mu_new = jnp.maximum(mu, mu_blk)
    alpha = jnp.exp(mu - mu_new)                        # rescale old state
    p = jnp.exp(s_eff - mu_new[..., None]) * valid_blk  # [..., Bk], in [0,1]
    z_new = alpha * z + jnp.sum(p, axis=-1)
    y_new = alpha[..., None] * y + jnp.einsum('...k,...kd->...d', p, v_blk)
    return SwiftKVState(mu=mu_new, z=z_new, y=y_new)


def state_merge(a: SwiftKVState, b: SwiftKVState) -> SwiftKVState:
    """Associative, commutative combine of two partial SwiftKV states.

    This is the property that lets the paper's single-pass recurrence shard
    across devices: each KV shard folds locally, partial triples merge in a
    tree (sequence-parallel decode), and the result is bit-for-bit the same
    math as one long scan up to fp reordering.
    """
    mu = jnp.maximum(a.mu, b.mu)
    ea = jnp.exp(a.mu - mu)
    eb = jnp.exp(b.mu - mu)
    return SwiftKVState(
        mu=mu,
        z=ea * a.z + eb * b.z,
        y=ea[..., None] * a.y + eb[..., None] * b.y,
    )


def state_finalize(state: SwiftKVState) -> jax.Array:
    """Eq. 8: the one deferred division. Fully-masked states return 0."""
    z = state.z[..., None]
    return jnp.where(z > 0, state.y / jnp.where(z > 0, z, 1.0), 0.0)


def swiftkv_decode_blockwise(q: jax.Array, k: jax.Array, v: jax.Array,
                             length: jax.Array | None = None,
                             k_scale: jax.Array | None = None,
                             v_scale: jax.Array | None = None,
                             *, block_size: int = 512,
                             window: int | None = None,
                             ring: bool = False,
                             scale: float | None = None) -> jax.Array:
    """Blockwise single-pass SwiftKV decode (the TPU-shaped reference that the
    Pallas kernel mirrors). q: [D]; k, v: [S, D].

    ``window``: sliding-window attention — only the last ``window`` cache
    entries attend (h2o-danube / hymba SWA); in-range blocks are touched
    once, with fully-out-of-window blocks contributing zero.

    ``ring``: the cache is a **ring** of R = S slots where slot ``s`` holds
    absolute position ``p - ((p - s) mod R)`` for ``p = length - 1`` (the
    newest token lives at ``(length-1) % R``). Validity is decided from
    that per-slot position instead of the slot index, so a wrapped cache is
    consumed in place — same single pass, no unrotate copy, no rescan; the
    ``(mu, Z, Y)`` recurrence is order-independent, so ring order and
    temporal order fold to the same result. Requires ``window`` (rings only
    exist for SWA configs).

    ``k_scale`` / ``v_scale``: optional [S] float per-position dequant scales
    for an **int8 KV cache** (``quantization.quantize_kv`` storage form).
    The scale multiply folds into the existing blockwise load — one extra
    [Bk] slice + broadcast per block, no second pass and no materialized
    f32 copy of the cache — so the int8 ring path keeps the zero-copy
    contract (position arithmetic only; asserted in
    tests/test_kernels_swiftkv.py).

    The loop trip count is **length-adaptive**: blocks past the valid
    prefix are exact state no-ops (every lane masked), so the loop runs
    ``cdiv(length, block_size)`` iterations — a traced bound that lowers to
    a ``while_loop``; under the ``decode_attention`` vmap the batch runs to
    the longest *active* row's count, so decode attention work scales with
    actual occupancy, not the cache allocation (a wrapped ring row runs all
    R slots — its whole working set). The static single-block case stays
    straight-line HLO (the dry-run cost pass sets ``block_size = seq_len``
    precisely so the loop disappears)."""
    if ring and window is None:
        raise ValueError("ring caches are windowed: pass window with ring=True")
    d = q.shape[-1]
    s_cache = k.shape[0]
    scale = (1.0 / jnp.sqrt(d)) if scale is None else scale
    length = jnp.asarray(s_cache if length is None else length, jnp.int32)
    n_blocks = -(-s_cache // block_size)
    pad = n_blocks * block_size - s_cache
    if pad:
        k = jnp.pad(k, ((0, pad), (0, 0)))
        v = jnp.pad(v, ((0, pad), (0, 0)))
        if k_scale is not None:
            k_scale = jnp.pad(k_scale, ((0, pad),))
            v_scale = jnp.pad(v_scale, ((0, pad),))
    qf = q.astype(jnp.float32)

    def body(i, state):
        start = i * block_size
        k_blk = jax.lax.dynamic_slice_in_dim(k, start, block_size).astype(jnp.float32)
        v_blk = jax.lax.dynamic_slice_in_dim(v, start, block_size).astype(jnp.float32)
        if k_scale is not None:
            k_blk = k_blk * jax.lax.dynamic_slice_in_dim(
                k_scale, start, block_size)[:, None]
            v_blk = v_blk * jax.lax.dynamic_slice_in_dim(
                v_scale, start, block_size)[:, None]
        t = start + jnp.arange(block_size)
        if ring:
            p = length - 1
            pos = p - jnp.mod(p - t, s_cache)       # slot -> absolute position
            valid = (t < s_cache) & (pos >= 0) & (pos > p - window)
        else:
            valid = t < length
            if window is not None:
                valid &= t >= length - window
        s_blk = (k_blk @ qf) * scale  # [Bk]
        return state_update_block(state, s_blk, v_blk, valid.astype(jnp.float32))

    init = state_init(v.shape[-1])
    if n_blocks == 1:
        state = body(0, init)
    else:
        n_live = jnp.minimum(n_blocks,
                             (length + block_size - 1) // block_size)
        state = jax.lax.fori_loop(0, n_live, body, init)
    return state_finalize(state).astype(q.dtype)


def swiftkv_decode_pooled(q: jax.Array, k_pool: jax.Array, v_pool: jax.Array,
                          entry: jax.Array, length: jax.Array,
                          k_scale: jax.Array | None = None,
                          v_scale: jax.Array | None = None, *,
                          block_size: int = 512,
                          scale: float | None = None) -> jax.Array:
    """Blockwise single-pass SwiftKV decode reading one entry of a shared
    **source-KV pool** — the ragged cross-attention read.

    q: [D]; k_pool, v_pool: [E, S, D] (E pooled entries of S rows each);
    ``entry``: which entry this query reads; ``length``: the entry's valid
    prefix (heterogeneous per batch row under the ``decode_cross_attention``
    vmap — rows with different encoder lengths coexist in one static-shape
    dispatch, each masking its own tail). The entry index is folded into
    the block loop's ``dynamic_slice`` start, so the read streams straight
    out of the pool — no per-step gather materializing a per-slot copy of
    the pool first. Cross-attention is non-causal and unwindowed: validity
    is just ``t < length``, and a ``length == 0`` row (no source) folds
    zero blocks and finalizes to an exact zero output.

    Same ``(mu, Z, Y)`` recurrence, same exactly-once single pass, same
    length-adaptive trip count as :func:`swiftkv_decode_blockwise` — the
    loop runs ``cdiv(length, block_size)`` iterations, so a short source
    costs attention work proportional to its own length, not the pool
    allocation.

    ``k_scale`` / ``v_scale``: optional [E, S] float per-(entry, position)
    dequant scales for an int8 source-KV pool — the entry-indirected
    analogue of the blockwise int8 read (one extra [Bk] slice per block)."""
    d = q.shape[-1]
    s_pool = k_pool.shape[1]
    scale = (1.0 / jnp.sqrt(d)) if scale is None else scale
    length = jnp.asarray(length, jnp.int32)
    entry = jnp.asarray(entry, jnp.int32)
    n_blocks = -(-s_pool // block_size)
    pad = n_blocks * block_size - s_pool
    if pad:
        k_pool = jnp.pad(k_pool, ((0, 0), (0, pad), (0, 0)))
        v_pool = jnp.pad(v_pool, ((0, 0), (0, pad), (0, 0)))
        if k_scale is not None:
            k_scale = jnp.pad(k_scale, ((0, 0), (0, pad)))
            v_scale = jnp.pad(v_scale, ((0, 0), (0, pad)))
    qf = q.astype(jnp.float32)

    def body(i, state):
        start = i * block_size
        k_blk = jax.lax.dynamic_slice(
            k_pool, (entry, start, 0), (1, block_size, d))[0].astype(jnp.float32)
        v_blk = jax.lax.dynamic_slice(
            v_pool, (entry, start, 0), (1, block_size, d))[0].astype(jnp.float32)
        if k_scale is not None:
            k_blk = k_blk * jax.lax.dynamic_slice(
                k_scale, (entry, start), (1, block_size))[0][:, None]
            v_blk = v_blk * jax.lax.dynamic_slice(
                v_scale, (entry, start), (1, block_size))[0][:, None]
        t = start + jnp.arange(block_size)
        valid = t < length
        s_blk = (k_blk @ qf) * scale  # [Bk]
        return state_update_block(state, s_blk, v_blk, valid.astype(jnp.float32))

    init = state_init(v_pool.shape[-1])
    if n_blocks == 1:
        state = body(0, init)
    else:
        n_live = jnp.minimum(n_blocks,
                             (length + block_size - 1) // block_size)
        state = jax.lax.fori_loop(0, n_live, body, init)
    return state_finalize(state).astype(q.dtype)


def swiftkv_decode_sharded_reference(q, k_shards, v_shards, lengths):
    """Pure-function model of sequence-parallel SwiftKV decode: fold each KV
    shard independently, then tree-merge the partial states. Used to prove the
    cross-device decomposition exact before it runs under shard_map."""
    states = []
    for k, v, ln in zip(k_shards, v_shards, lengths):
        d = q.shape[-1]
        scale = 1.0 / jnp.sqrt(d)
        t = jnp.arange(k.shape[0])
        s = (k.astype(jnp.float32) @ q.astype(jnp.float32)) * scale
        states.append(state_update_block(
            state_init(v.shape[-1]), s, v.astype(jnp.float32),
            (t < ln).astype(jnp.float32)))
    acc = states[0]
    for st in states[1:]:
        acc = state_merge(acc, st)
    return state_finalize(acc).astype(q.dtype)


# ---------------------------------------------------------------------------
# Oracle
# ---------------------------------------------------------------------------

def softmax_attention_reference(q: jax.Array, k: jax.Array, v: jax.Array,
                                length: jax.Array | None = None,
                                *, window: int | None = None,
                                scale: float | None = None) -> jax.Array:
    """Naive two-pass softmax attention (Eq. 4) — the correctness oracle.
    Materializes the full score vector (exactly what SwiftKV avoids)."""
    d = q.shape[-1]
    s_cache = k.shape[0]
    scale = (1.0 / jnp.sqrt(d)) if scale is None else scale
    length = jnp.asarray(s_cache if length is None else length)
    s = (k.astype(jnp.float32) @ q.astype(jnp.float32)) * scale
    t = jnp.arange(s_cache)
    valid = t < length
    if window is not None:
        valid &= t >= length - window
    s = jnp.where(valid, s, NEG_INF)
    p = jax.nn.softmax(s)
    p = jnp.where(valid, p, 0.0)
    return (p @ v.astype(jnp.float32)).astype(q.dtype)
