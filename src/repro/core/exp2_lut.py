"""LUT-based exponential (paper Eqs. 9-10).

``exp(x) = 2^{x log2 e} = 2^{n + f}`` with integer ``n <= 0`` (bit shift) and
fractional ``f in (-1, 0]`` approximated by a 32-entry lookup table with linear
interpolation:

    u = -f in [0, 1);   i = top 5 fractional bits of u;  f2 = remaining bits
    2^f ~= LUT[i] + delta_i * f2,   LUT[i] = 2^{-i/32}

Paper claim: max relative error 0.00586% over (-1, 0] — reproduced by
``benchmarks/lut_exp_error.py`` and asserted in tests.

Two realizations:
  * float path (``exp2_lut`` / ``exp_lut``) — jnp, used inside the Pallas
    kernel's ``exp_mode="lut"`` via a one-hot matmul (TPU-lowerable gather).
  * Q15.17 integer path (``exp_lut_fxp``) — numpy int64, bit-accurate to the
    hardware datapath described in §III (5-bit index + 12-bit interpolant).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

LOG2_E = 1.4426950408889634
LUT_BITS = 5
LUT_SIZE = 1 << LUT_BITS          # 32
FRAC_BITS = 17                    # Q15.17
F2_BITS = FRAC_BITS - LUT_BITS    # 12


def make_lut() -> tuple[np.ndarray, np.ndarray]:
    """Returns (values, slopes): LUT[i] = 2^{-i/32}; slope_i interpolates to
    LUT[i+1] (with LUT[32] = 0.5) over the f2 in [0,1) sub-interval."""
    i = np.arange(LUT_SIZE + 1)
    vals = 2.0 ** (-i / LUT_SIZE)
    slopes = vals[1:] - vals[:-1]          # negative; per unit of f2 in [0,1)
    return vals[:-1], slopes


_LUT_VALS, _LUT_SLOPES = make_lut()
LUT_VALS = jnp.asarray(_LUT_VALS, jnp.float32)
LUT_SLOPES = jnp.asarray(_LUT_SLOPES, jnp.float32)


def exp2_frac_lut(f: jax.Array) -> jax.Array:
    """2^f for f in (-1, 0] via Eq. 10 (float realization)."""
    u = -f                                       # [0, 1)
    scaled = u * LUT_SIZE
    idx = jnp.clip(scaled.astype(jnp.int32), 0, LUT_SIZE - 1)
    f2 = scaled - idx                            # [0, 1)
    # one-hot matmul gather: lowers cleanly on the TPU MXU (no 1D gather op)
    onehot = jax.nn.one_hot(idx, LUT_SIZE, dtype=f.dtype)
    base = onehot @ LUT_VALS.astype(f.dtype)
    slope = onehot @ LUT_SLOPES.astype(f.dtype)
    return base + slope * f2


def exp_lut(x: jax.Array) -> jax.Array:
    """exp(x) for x <= 0 via Eq. 9: 2^{n+f}, n = ceil(y) <= 0, f in (-1, 0]."""
    y = x * LOG2_E
    n = jnp.ceil(y)
    f = y - n
    frac = exp2_frac_lut(f)
    return jnp.ldexp(frac, n.astype(jnp.int32))


# ---------------------------------------------------------------------------
# Bit-accurate Q15.17 integer datapath (numpy; validation oracle)
# ---------------------------------------------------------------------------

# table entries and slopes stored in Q15.17; slopes are per-unit-of-f2 where
# f2 is the 12-bit remainder (value f2 / 2^12 of one LUT step = /2^17 of 1.0)
_LUT_VALS_FXP = np.round(_LUT_VALS * (1 << FRAC_BITS)).astype(np.int64)
_NEXT = np.round(np.append(_LUT_VALS, 0.5) * (1 << FRAC_BITS)).astype(np.int64)
_LUT_SLOPES_FXP = _NEXT[1:] - _NEXT[:-1]   # delta over one step, Q15.17


def exp_lut_fxp(x_fxp: np.ndarray) -> np.ndarray:
    """exp(x) on Q15.17 integers, x <= 0. Integer-only except the final value
    is returned still in Q15.17. Mirrors the §III hardware datapath: multiply
    by log2(e) (Q15.17 constant), split n/f, 5-bit LUT index, 12-bit linear
    interpolation (Eq. 10), then an n-bit right shift for 2^n."""
    x_fxp = np.asarray(x_fxp, np.int64)
    log2e = np.int64(round(LOG2_E * (1 << FRAC_BITS)))
    y = (x_fxp * log2e) >> FRAC_BITS                      # Q15.17, y <= 0
    # n = ceil(y / 2^17): floor-division plus one when a remainder exists
    n = np.where(y % (1 << FRAC_BITS) == 0, y >> FRAC_BITS, (y >> FRAC_BITS) + 1)
    f = y - (n << FRAC_BITS)                              # in (-2^17, 0]
    u = -f                                                # [0, 2^17)
    idx = (u >> F2_BITS).astype(np.int64)                 # 5-bit index
    f2 = u & ((1 << F2_BITS) - 1)                         # 12-bit remainder
    base = _LUT_VALS_FXP[idx]
    slope = _LUT_SLOPES_FXP[idx]
    frac = base + ((slope * f2 + (1 << (F2_BITS - 1))) >> F2_BITS)  # Q15.17, rounded
    shift = (-n).astype(np.int64)                         # n <= 0
    shift = np.minimum(shift, 62)
    return frac >> shift                                  # 2^{n}·2^{f}, Q15.17


def max_relative_error(num_points: int = 200_000) -> float:
    """Max relative error of the float LUT path over (-1, 0] (paper: 5.86e-5)."""
    f = -np.linspace(1e-9, 1.0 - 1e-9, num_points, dtype=np.float64)
    approx = np.asarray(exp2_frac_lut(jnp.asarray(f, jnp.float64)
                                      if jax.config.jax_enable_x64
                                      else jnp.asarray(f, jnp.float32)))
    exact = 2.0 ** f
    return float(np.max(np.abs(approx.astype(np.float64) - exact) / exact))
