"""Serving driver: prefill + per-token decode (the paper's workload).

Two modes over host devices (reduced configs) or a production mesh:

* **lock-step** (default) — the ``ServingEngine`` batch: uniform-length
  prompts, prefill once, decode in lock-step. The decode step is the unit
  the dry-run lowers for the ``decode_*`` shape cells.
* **continuous** (``--continuous``) — the ragged continuous-batching
  subsystem (``repro.serving.continuous``): KV slot pool + source-KV pool +
  request scheduler + chunked slot prefill + multi-tick decode blocks
  (``--decode-ticks``), driven by a Poisson or file trace, with per-request
  TTFT / inter-token latency, slot-occupancy, and dispatch-accounting
  metrics.
  Covers **every** family: dense-KV, ring-KV SWA (``<arch>+ring``),
  recurrent-state (ssm / hybrid: rwkv6-3b, hymba-1.5b), MoE (olmoe-1b-7b,
  llama4-scout), and cross-attention stacks (vlm / audio: whisper-small,
  llama-3.2-vision-90b — Poisson traces get heterogeneous-length sources
  with shared source ids, served through the source-KV pool).

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --reduced \
        --batch 4 --prompt-len 32 --gen 64
    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b --reduced \
        --continuous --requests 16 --n-slots 4 --max-len 256
    PYTHONPATH=src python -m repro.launch.serve --arch whisper-small \
        --reduced --continuous --requests 8 --n-slots 2 --max-len 64
"""
from __future__ import annotations

import argparse
import json
import logging
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.api import build_model, needs_source
from repro.serving import (ContinuousBatchingEngine, EngineAuditor,
                           OverloadConfig, ServingEngine, Telemetry,
                           load_trace, poisson_trace)
from repro.serving.scheduler import SHED_POLICIES
from repro.serving.workload import TRACE_SHAPES

log = logging.getLogger("repro.launch.serve")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=64)
    ap.add_argument("--max-len", type=int, default=0, help="default: pow2 fit")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--decode-impl", default=None,
                    choices=["blockwise", "tokenwise", "kernel", "naive"])
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--metrics-out")
    # --- continuous batching ---
    ap.add_argument("--continuous", action="store_true",
                    help="ragged continuous batching over a request trace "
                         "(every family: dense, ring, ssm, hybrid, MoE, "
                         "and cross-attention via the source-KV pool)")
    ap.add_argument("--n-slots", type=int, default=0,
                    help="KV slot pool size (default: --batch)")
    ap.add_argument("--requests", type=int, default=16,
                    help="continuous: trace length")
    ap.add_argument("--chunk", type=int, default=16,
                    help="continuous: prefill chunk size")
    ap.add_argument("--decode-ticks", type=int, default=1,
                    help="continuous: fused decode ticks per dispatch (K) — "
                         "the host syncs once per K tokens; on-device "
                         "EOS/budget retirement keeps outputs exact at any "
                         "K, the adaptive horizon drops to 1 while prefill "
                         "chunks are waiting")
    ap.add_argument("--rate", type=float, default=None,
                    help="continuous: mean arrival rate req/s "
                         "(default: backlogged)")
    ap.add_argument("--trace-shape", default="poisson",
                    choices=list(TRACE_SHAPES),
                    help="continuous: interarrival shape — poisson "
                         "(well-behaved), bursty (near-simultaneous "
                         "clumps), heavy-tail (Lomax gaps); overload "
                         "control is exercised by the latter two")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="continuous: bound the admission queue "
                         "(overload control; default unbounded)")
    ap.add_argument("--shed-policy", default="reject",
                    choices=list(SHED_POLICIES),
                    help="continuous: what a full queue does — reject the "
                         "incoming request, shed the oldest queued one, or "
                         "degrade everyone's decode budget")
    ap.add_argument("--audit", action="store_true",
                    help="continuous: run the engine invariant auditor "
                         "after every decode block")
    ap.add_argument("--trace", default=None,
                    help="continuous: JSON trace file instead of generated "
                         "arrivals")
    ap.add_argument("--eos-id", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace-out", default=None,
                    help="continuous: write the run's telemetry as a "
                         "Chrome/Perfetto trace (open the .trace.json at "
                         "https://ui.perfetto.dev — one lane per slot)")
    ap.add_argument("--events-out", default=None,
                    help="continuous: stream raw telemetry events as JSONL "
                         "(convert later with tools/trace_viewer.py)")
    args = ap.parse_args(argv)

    logging.basicConfig(level=logging.INFO)
    cfg = get_config(args.arch, reduced=args.reduced)
    if args.decode_impl:
        cfg = cfg.replace(decode_impl=args.decode_impl)
    model = build_model(cfg)
    mesh = (make_production_mesh() if args.production_mesh
            else make_host_mesh())

    rng = jax.random.PRNGKey(0)
    params = model.init_params(rng)

    if args.continuous:
        return _run_continuous(args, cfg, model, params, mesh)
    return _run_lockstep(args, cfg, model, params, mesh)


def _run_lockstep(args, cfg, model, params, mesh):
    need = args.prompt_len + args.gen
    max_len = args.max_len or (1 << (need - 1).bit_length())
    src = None
    if needs_source(cfg):
        src = jax.random.normal(
            jax.random.PRNGKey(1),
            (args.batch, cfg.source_len, cfg.d_model),
            jnp.dtype(cfg.compute_dtype)) * 0.02

    with mesh:
        eng = ServingEngine(model, params, max_len=max_len, batch=args.batch,
                            source_len=cfg.source_len if src is not None
                            else None)
        prompts = jax.random.randint(
            jax.random.PRNGKey(2), (args.batch, args.prompt_len), 0,
            cfg.vocab_size, jnp.int32)
        # warmup (compile)
        _ = eng.generate(prompts, steps=2, temperature=args.temperature,
                         source=src)
        t0 = time.perf_counter()
        out = eng.generate(prompts, steps=args.gen,
                           temperature=args.temperature, source=src)
        wall = time.perf_counter() - t0

    toks = args.batch * args.gen
    metrics = {"arch": args.arch, "batch": args.batch,
               "prompt_len": args.prompt_len, "generated": args.gen,
               "wall_s": round(wall, 3), "tokens_per_s": round(toks / wall, 1),
               "ms_per_token_step": round(1e3 * wall / args.gen, 2)}
    log.info("%s", metrics)
    print(json.dumps(metrics))
    if args.metrics_out:
        Path(args.metrics_out).write_text(json.dumps(metrics, indent=1))
    return out, metrics


def _run_continuous(args, cfg, model, params, mesh):
    n_slots = args.n_slots or args.batch
    max_len = args.max_len or 256
    if args.trace:
        trace = load_trace(args.trace, cfg.vocab_size)
    else:
        src_kw = {}
        if needs_source(cfg):
            # cross-attention stacks: heterogeneous source lengths + a
            # shared source id every other pair (source-KV pool dedup)
            src_kw = dict(source_len=(max(1, cfg.source_len // 4),
                                      cfg.source_len),
                          source_dim=cfg.d_model, source_share=2)
        trace = poisson_trace(
            n_requests=args.requests, vocab_size=cfg.vocab_size,
            rate=args.rate, prompt_len=(min(8, args.prompt_len),
                                        args.prompt_len),
            max_new=(min(4, args.gen), args.gen), seed=args.seed,
            shape=args.trace_shape, **src_kw)

    telemetry = (Telemetry(jsonl_path=args.events_out)
                 if (args.trace_out or args.events_out) else None)
    overload = (OverloadConfig(max_queue=args.max_queue,
                               policy=args.shed_policy)
                if args.max_queue else None)
    with mesh:
        eng = ContinuousBatchingEngine(
            model, params, n_slots=n_slots, max_len=max_len,
            chunk=args.chunk, eos_id=args.eos_id,
            temperature=args.temperature, seed=args.seed,
            decode_ticks=args.decode_ticks, telemetry=telemetry,
            overload=overload,
            auditor=EngineAuditor() if args.audit else None)
        eng.warmup()
        # a Ctrl-C lands inside run(), which drains gracefully: the
        # in-flight block finishes, queued requests shed with a typed
        # code, conservation still holds, and the report comes back with
        # interrupted: true — so the telemetry/trace sinks below always
        # flush instead of losing the JSONL tail
        report = eng.run(trace)
        if report["aggregate"].get("interrupted"):
            log.warning("run interrupted: %d shed, %d retired with partial "
                        "tokens", report["aggregate"]["n_shed"],
                        report["aggregate"]["n_retired"])
    if telemetry is not None:
        if args.trace_out:
            path = telemetry.write_chrome_trace(args.trace_out)
            log.info("wrote Perfetto trace (%d events) -> %s",
                     len(telemetry.events), path)
        telemetry.close()

    metrics = {"arch": args.arch, "mode": "continuous", "n_slots": n_slots,
               "max_len": max_len, "chunk": args.chunk,
               **report["aggregate"]}
    log.info("%s", metrics)
    print(json.dumps(metrics))
    if args.metrics_out:
        Path(args.metrics_out).write_text(json.dumps(
            {"metrics": metrics, "requests": report["requests"]}, indent=1))
    return report, metrics


if __name__ == "__main__":
    main()
