"""Serving driver: prefill + per-token decode (the paper's workload).

Runs the ``ServingEngine`` over host devices (reduced configs) or a
production mesh. The decode step is the unit the dry-run lowers for the
``decode_*`` shape cells; here it actually executes and reports tokens/s.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --reduced \
        --batch 4 --prompt-len 32 --gen 64
"""
from __future__ import annotations

import argparse
import json
import logging
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.api import build_model, needs_source
from repro.serving import ServingEngine

log = logging.getLogger("repro.launch.serve")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=64)
    ap.add_argument("--max-len", type=int, default=0, help="default: pow2 fit")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--decode-impl", default=None,
                    choices=["blockwise", "tokenwise", "kernel", "naive"])
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--metrics-out")
    args = ap.parse_args(argv)

    logging.basicConfig(level=logging.INFO)
    cfg = get_config(args.arch, reduced=args.reduced)
    if args.decode_impl:
        cfg = cfg.replace(decode_impl=args.decode_impl)
    model = build_model(cfg)
    mesh = (make_production_mesh() if args.production_mesh
            else make_host_mesh())

    need = args.prompt_len + args.gen
    max_len = args.max_len or (1 << (need - 1).bit_length())
    rng = jax.random.PRNGKey(0)
    params = model.init_params(rng)
    src = None
    if needs_source(cfg):
        src = jax.random.normal(
            jax.random.PRNGKey(1),
            (args.batch, cfg.source_len, cfg.d_model),
            jnp.dtype(cfg.compute_dtype)) * 0.02

    with mesh:
        eng = ServingEngine(model, params, max_len=max_len, batch=args.batch,
                            source_len=cfg.source_len if src is not None
                            else None)
        prompts = jax.random.randint(
            jax.random.PRNGKey(2), (args.batch, args.prompt_len), 0,
            cfg.vocab_size, jnp.int32)
        # warmup (compile)
        _ = eng.generate(prompts, steps=2, temperature=args.temperature,
                         source=src)
        t0 = time.perf_counter()
        out = eng.generate(prompts, steps=args.gen,
                           temperature=args.temperature, source=src)
        wall = time.perf_counter() - t0

    toks = args.batch * args.gen
    metrics = {"arch": args.arch, "batch": args.batch,
               "prompt_len": args.prompt_len, "generated": args.gen,
               "wall_s": round(wall, 3), "tokens_per_s": round(toks / wall, 1),
               "ms_per_token_step": round(1e3 * wall / args.gen, 2)}
    log.info("%s", metrics)
    print(json.dumps(metrics))
    if args.metrics_out:
        Path(args.metrics_out).write_text(json.dumps(metrics, indent=1))
    return out, metrics


if __name__ == "__main__":
    main()
