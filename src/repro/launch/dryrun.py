"""Multi-pod dry-run (deliverable e) + roofline extraction (deliverable g).

Proves the distribution config is coherent without hardware: for every
(architecture x input-shape) cell, ``jit(step).lower(...).compile()`` must
succeed on the 16x16 single-pod mesh AND the 2x16x16 multi-pod mesh, with
``memory_analysis()`` showing it fits and ``cost_analysis()`` + the optimized
HLO feeding the roofline terms.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --out reports/dryrun
    PYTHONPATH=src python -m repro.launch.dryrun --all --reduced   # machinery smoke
"""
# The dry-run needs 512 placeholder devices; jax locks the device count on
# first init, so this MUST precede every other import (including repro.*).
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

import argparse      # noqa: E402
import dataclasses   # noqa: E402
import functools     # noqa: E402
import json          # noqa: E402
import subprocess    # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402
from pathlib import Path  # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ARCH_IDS, ASSIGNED_ARCHS, SHAPES, get_config  # noqa: E402
from repro.distributed import roofline  # noqa: E402
from repro.distributed.context import set_context  # noqa: E402
from repro.distributed.sharding import (MeshRules, batch_specs, cache_specs,  # noqa: E402
                                        fixup_divisibility, fixup_tree, named,
                                        param_specs)
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models.api import build_model, input_specs, needs_source  # noqa: E402
from repro.models.config import shape_applicable  # noqa: E402
from repro.optim import AdamWState, adamw_init  # noqa: E402
from repro.train import make_train_step  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402


def _cost_dict(cost) -> dict:
    """Normalize ``cost_analysis()`` across jax releases: 0.4.x returns a
    one-element list of dicts, newer releases return the dict directly (and
    either may return None on backends without an analysis)."""
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else None
    return cost if isinstance(cost, dict) else {}


# ---------------------------------------------------------------------------
# Step builders: one lowered unit per shape kind
# ---------------------------------------------------------------------------

def build_cell(cfg, shape, mesh, *, microbatches: int = 1,
               train_opts: dict | None = None):
    """Returns (fn, example_args, in_shardings, out_shardings)."""
    rules = MeshRules(mesh)
    set_context(mesh, batch_axes=rules.batch_axes, model_axis="model")
    model = build_model(cfg)
    specs = input_specs(cfg, shape)
    params_shapes = jax.eval_shape(
        functools.partial(model.init_params, jax.random.PRNGKey(0)))
    if shape.kind != "train":
        # serving stores weights in the compute dtype (bf16); training keeps
        # f32 masters (the optimizer state) and casts at use.
        cdt = jnp.dtype(cfg.compute_dtype)
        params_shapes = jax.tree.map(
            lambda s: (jax.ShapeDtypeStruct(s.shape, cdt)
                       if s.dtype == jnp.float32 and s.ndim >= 2 else s),
            params_shapes)
        if cfg.w4a8_serve:
            from repro.models.quantized import quantize_params
            params_shapes = jax.eval_shape(quantize_params, params_shapes)

    if shape.kind == "train":
        bspecs = fixup_tree(batch_specs(cfg, shape, rules), specs, mesh)
        pspec = param_specs(params_shapes, rules, train=True)
        opt_shapes = jax.eval_shape(adamw_init, params_shapes)
        ospec = AdamWState(step=P(), mu=pspec, nu=pspec)
        step = make_train_step(model, microbatches=microbatches,
                               param_specs=pspec, **(train_opts or {}))
        args = (params_shapes, opt_shapes, specs)
        in_sh = (named(pspec, mesh), named(ospec, mesh), named(bspecs, mesh))
        out_sh = (named(pspec, mesh), named(ospec, mesh), None)
        return step, args, in_sh, out_sh

    pspec = param_specs(params_shapes, rules, train=False)
    src_len = cfg.source_len if needs_source(cfg) else None
    if shape.kind == "prefill":
        cache_shapes = jax.eval_shape(functools.partial(
            model.init_cache, shape.global_batch, shape.seq_len, src_len))
        cspec = fixup_tree(cache_specs(cfg, shape, rules), cache_shapes, mesh)
        bspecs = fixup_tree(batch_specs(cfg, shape, rules), specs, mesh)

        def prefill_step(params, batch):
            b, s = batch["tokens"].shape
            cache = model.init_cache(b, s, src_len)
            cache = jax.lax.with_sharding_constraint(cache, named(cspec, mesh))
            logits, cache = model.prefill(params, batch["tokens"], cache,
                                          batch.get("source"))
            return logits, cache

        args = (params_shapes, specs)
        in_sh = (named(pspec, mesh), named(bspecs, mesh))
        out_sh = (None, named(cspec, mesh))
        return prefill_step, args, in_sh, out_sh

    # decode: serve_step — one token for every sequence in the batch
    cspec = fixup_tree(cache_specs(cfg, shape, rules), specs["cache"], mesh)
    tok_spec = fixup_divisibility(
        batch_specs(cfg, shape, rules)["tokens"],
        specs["tokens"].shape, mesh)

    def serve_step(params, tokens, cache):
        return model.decode_step(params, tokens, cache)

    args = (params_shapes, specs["tokens"], specs["cache"])
    in_sh = (named(pspec, mesh), named(tok_spec, mesh), named(cspec, mesh))
    out_sh = (None, named(cspec, mesh))
    return serve_step, args, in_sh, out_sh


# ---------------------------------------------------------------------------
# One cell: lower + compile + analyze
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             reduced: bool = False, microbatches: int | None = None,
             save_hlo: str | None = None, unroll: bool = False,
             overrides: dict | None = None) -> dict:
    cfg = get_config(arch, reduced=reduced)
    shape = SHAPES[shape_name]
    # ``unroll`` is the roofline COST pass: python-loop the layer stack,
    # single KV block, no microbatch scan — every loop XLA would cost once
    # is flattened, so flops/bytes/collectives are trip-count-true. The
    # scanned pass is the production program (memory/fits comes from it).
    # ``overrides`` feed the perf hillclimb.
    ov = dict(overrides or {})
    if unroll:
        ov.setdefault("attn_block", shape.seq_len)
    cfg = cfg.replace(unroll_layers=unroll, **ov)
    if microbatches is None:
        microbatches = 1 if unroll else (8 if shape.kind == "train" else 1)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    report = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
              "kind": shape.kind, "mode": "unroll" if unroll else "scan",
              "microbatches": microbatches, "ok": False}

    runs, reason = shape_applicable(cfg, shape)
    if not runs:
        report.update(skipped=True, reason=reason, ok=True)
        return report

    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = MeshRules(mesh)
    n_chips = mesh.devices.size
    try:
        fn, args, in_sh, out_sh = build_cell(cfg, shape, mesh,
                                             microbatches=microbatches)
        t0 = time.perf_counter()
        with mesh:
            lowered = jax.jit(fn, in_shardings=in_sh,
                              out_shardings=out_sh).lower(*args)
            t_lower = time.perf_counter() - t0
            # platform-independent pre-partition costs: true-dtype bytes
            # (the CPU backend's bf16->f32 converts inflate compiled bytes)
            lca = _cost_dict(lowered.cost_analysis())
            t0 = time.perf_counter()
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t0

        mem = compiled.memory_analysis()
        cost = _cost_dict(compiled.cost_analysis())
        hlo = compiled.as_text()
        if save_hlo:
            Path(save_hlo).write_text(hlo)

        bytes_per_chip = (mem.argument_size_in_bytes
                          + mem.temp_size_in_bytes
                          + mem.output_size_in_bytes
                          - mem.alias_size_in_bytes)
        # memory term: lowered (global, dtype-true) bytes spread over chips;
        # compute term: compiled per-chip FLOPs (includes padding waste)
        terms = {"flops": float(cost.get("flops", 0.0)),
                 "bytes accessed":
                     float(lca.get("bytes accessed", 0.0)) / n_chips}
        rep = roofline.analyze(
            arch, shape_name, mesh_name, n_chips, terms, hlo,
            bytes_per_chip=bytes_per_chip,
            model_flops=roofline.model_flops_for_cell(cfg, shape),
            tp_size=rules.tp_size)
        rep_extra = {
            "compiled_bytes_per_chip_gb":
                float(cost.get("bytes accessed", 0.0)) / 1e9,
            "lowered_global_gflops": float(lca.get("flops", 0.0)) / 1e9,
        }

        report.update(
            ok=True,
            lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
            memory={
                "argument_gb": mem.argument_size_in_bytes / 1e9,
                "output_gb": mem.output_size_in_bytes / 1e9,
                "temp_gb": mem.temp_size_in_bytes / 1e9,
                "alias_gb": mem.alias_size_in_bytes / 1e9,
                "per_chip_gb": bytes_per_chip / 1e9,
                "fits_16gb": bytes_per_chip < 16e9,
            },
            roofline={**rep.row(), **rep_extra},
        )
    except Exception as e:  # a failure here is a bug in our sharding config
        report.update(ok=False, error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-3000:])
    return report


def print_report(rep: dict):
    if rep.get("skipped"):
        print(f"[SKIP] {rep['arch']} x {rep['shape']} ({rep['mesh']}): "
              f"{rep['reason']}")
        return
    if not rep["ok"]:
        print(f"[FAIL] {rep['arch']} x {rep['shape']} ({rep['mesh']}): "
              f"{rep['error']}")
        return
    m, r = rep["memory"], rep["roofline"]
    print(f"[ OK ] {rep['arch']} x {rep['shape']} ({rep['mesh']} "
          f"{rep.get('mode', 'scan')}) lower={rep.get('lower_s', '-')}s "
          f"compile={rep.get('compile_s', '-')}s")
    if "argument_gb" in m:
        print(f"       mem/chip={m['per_chip_gb']:.2f} GB "
              f"(args={m['argument_gb']:.2f} temp={m['temp_gb']:.2f}; "
              f"fits 16GB: {m['fits_16gb']})")
    print(f"       t_compute={r['t_compute_ms']:.3f}ms "
          f"t_memory={r['t_memory_ms']:.3f}ms "
          f"t_collective={r['t_collective_ms']:.3f}ms "
          f"-> {r['dominant']}-bound; useful={100 * r['useful_frac']:.1f}% "
          f"roofline={100 * r['roofline_frac']:.1f}%")
    print(f"       collectives: {r['op_counts']}")


# ---------------------------------------------------------------------------
# Cost pass via layer-pair extrapolation
# ---------------------------------------------------------------------------

def _layer_pair(cfg) -> tuple[int, int, int]:
    """(L_small, L_big, L_full) preserving the arch's layer-group structure."""
    if cfg.cross_attn_every > 1:                 # vlm: groups of N layers
        g = cfg.cross_attn_every
        return g, 2 * g, cfg.n_layers
    return 2, 4, cfg.n_layers


def _cfg_with_layers(cfg, n_layers: int):
    kw = {"n_layers": n_layers}
    if cfg.encoder_layers:
        kw["encoder_layers"] = n_layers
    return cfg.replace(**kw)


def _extract_costs(cfg, shape, mesh, rules, microbatches=1,
                   train_opts=None):
    fn, args, in_sh, out_sh = build_cell(cfg, shape, mesh,
                                         microbatches=microbatches,
                                         train_opts=train_opts)
    with mesh:
        lowered = jax.jit(fn, in_shardings=in_sh,
                          out_shardings=out_sh).lower(*args)
        lca = _cost_dict(lowered.cost_analysis())
        compiled = lowered.compile()
    cost = _cost_dict(compiled.cost_analysis())
    stats = roofline.parse_collectives(compiled.as_text(),
                                       default_group=rules.tp_size)
    return {
        "chip_flops": float(cost.get("flops", 0.0)),
        "global_bytes": float(lca.get("bytes accessed", 0.0)),
        "ici_bytes": stats.ici_bytes,
        "op_counts": dict(stats.op_counts),
        "op_bytes": dict(stats.op_bytes),
    }


def run_cost_cell(arch: str, shape_name: str, *, reduced: bool = False,
                  overrides: dict | None = None,
                  train_opts: dict | None = None) -> dict:
    """Roofline COST extraction: unrolled layers, single KV block, no
    microbatch scan — lowered at a small/big layer pair and extrapolated
    linearly to the full depth (per-layer cost is L-independent for these
    homogeneous stacks, so the extrapolation is exact; validated against
    full unrolls in EXPERIMENTS.md §Dry-run)."""
    cfg0 = get_config(arch, reduced=reduced)
    shape = SHAPES[shape_name]
    report = {"arch": arch, "shape": shape_name, "mesh": "16x16",
              "kind": shape.kind, "mode": "unroll-extrap", "ok": False}
    runs, reason = shape_applicable(cfg0, shape)
    if not runs:
        report.update(skipped=True, reason=reason, ok=True)
        return report

    ov = dict(overrides or {})
    ov.setdefault("attn_block", shape.seq_len)
    ov.setdefault("unroll_layers", True)
    cfg = cfg0.replace(**ov)
    l_small, l_big, l_full = _layer_pair(cfg)
    # encoder-decoder: scale both stacks; count total scaled layers
    denom_small = l_small * (2 if cfg.encoder_layers else 1)
    denom_big = l_big * (2 if cfg.encoder_layers else 1)
    denom_full = l_full * (2 if cfg.encoder_layers else 1)

    mesh = make_production_mesh(multi_pod=False)
    rules = MeshRules(mesh)
    try:
        t0 = time.perf_counter()
        c_small = _extract_costs(_cfg_with_layers(cfg, l_small), shape, mesh,
                                 rules, train_opts=train_opts)
        c_big = _extract_costs(_cfg_with_layers(cfg, l_big), shape, mesh,
                               rules, train_opts=train_opts)
        wall = time.perf_counter() - t0

        def extrap(key):
            delta = ((c_big[key] - c_small[key])
                     / (denom_big - denom_small))
            return c_big[key] + delta * (denom_full - denom_big)

        flops = extrap("chip_flops")
        gbytes = extrap("global_bytes")
        ici = extrap("ici_bytes")
        scale_counts = (denom_full - denom_big) / (denom_big - denom_small)
        op_counts = {
            k: int(round(c_big[k2] if False else c_big["op_counts"].get(k, 0)
                         + (c_big["op_counts"].get(k, 0)
                            - c_small["op_counts"].get(k, 0)) * scale_counts))
            for k in set(c_big["op_counts"]) | set(c_small["op_counts"])}

        n_chips = mesh.devices.size
        rep = roofline.RooflineReport(
            arch=arch, shape=shape_name, mesh="16x16", n_chips=n_chips,
            hlo_flops=flops, hlo_bytes=gbytes / n_chips,
            collective_op_bytes=0, collective_ici_bytes=ici,
            bytes_per_chip=0.0,
            model_flops=roofline.model_flops_for_cell(cfg0, shape),
            op_counts=op_counts).finalize()
        op_bytes = {
            k: (c_big["op_bytes"].get(k, 0)
                + (c_big["op_bytes"].get(k, 0)
                   - c_small["op_bytes"].get(k, 0)) * scale_counts)
            for k in set(c_big["op_bytes"]) | set(c_small["op_bytes"])}
        report.update(ok=True, compile_s=round(wall, 2),
                      layer_pair=[l_small, l_big, l_full],
                      memory={"per_chip_gb": float("nan"),
                              "fits_16gb": None},
                      roofline=rep.row(),
                      op_gbytes={k: round(v / 1e9, 3)
                                 for k, v in op_bytes.items()})
    except Exception as e:
        report.update(ok=False, error=f"{type(e).__name__}: {e}",
                      traceback=traceback.format_exc()[-3000:])
    return report


# ---------------------------------------------------------------------------
# --all driver: every cell in a fresh subprocess (memory isolation)
# ---------------------------------------------------------------------------

def all_cells() -> list[tuple[str, str]]:
    return [(a, s) for a in ASSIGNED_ARCHS for s in SHAPES]


def run_all(out_dir: Path, *, reduced: bool, timeout: int = 3600,
            archs=None, shapes=None):
    """Three passes per cell: (16x16, scan), (2x16x16, scan) — the multi-pod
    lowering proof — and (16x16, unroll) — the roofline-term extraction."""
    out_dir.mkdir(parents=True, exist_ok=True)
    results = []
    passes = [(False, False), (True, False), (False, True)]  # (mp, unroll)
    for arch, shape in all_cells():
        if archs and arch not in archs:
            continue
        if shapes and shape not in shapes:
            continue
        for mp, unroll in passes:
            tag = (f"{arch}__{shape}__{'mp' if mp else 'sp'}"
                   f"{'__unroll' if unroll else ''}")
            fout = out_dir / f"{tag}.json"
            if fout.exists():
                rep = json.loads(fout.read_text())
                if rep.get("ok"):
                    results.append(rep)
                    print(f"[CACHED] {tag}")
                    continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--json", str(fout)]
            if mp:
                cmd.append("--multi-pod")
            if unroll:
                cmd.append("--cost")   # layer-pair extrapolated cost pass
            if reduced:
                cmd.append("--reduced")
            t0 = time.perf_counter()
            try:
                proc = subprocess.run(cmd, capture_output=True, text=True,
                                      timeout=timeout)
                rep = (json.loads(fout.read_text()) if fout.exists() else
                       {"arch": arch, "shape": shape, "ok": False,
                        "mesh": "2x16x16" if mp else "16x16",
                        "mode": "unroll" if unroll else "scan",
                        "error": proc.stderr[-2000:]})
            except subprocess.TimeoutExpired:
                rep = {"arch": arch, "shape": shape, "ok": False,
                       "mesh": "2x16x16" if mp else "16x16",
                       "mode": "unroll" if unroll else "scan",
                       "error": f"timeout after {timeout}s"}
                fout.write_text(json.dumps(rep, indent=1))
            rep.setdefault("wall_s", round(time.perf_counter() - t0, 1))
            results.append(rep)
            print_report(rep)
    summarize(results, out_dir)
    return results


def summarize(results: list[dict], out_dir: Path):
    ok = sum(1 for r in results if r.get("ok") and not r.get("skipped"))
    skip = sum(1 for r in results if r.get("skipped"))
    fail = sum(1 for r in results if not r.get("ok"))
    print(f"\n=== dry-run summary: {ok} ok, {skip} skipped, {fail} failed "
          f"of {len(results)} ===")
    (out_dir / "summary.json").write_text(json.dumps(results, indent=1))


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", help="architecture id (e.g. qwen3-8b)")
    ap.add_argument("--shape", choices=list(SHAPES), help="input-shape cell")
    ap.add_argument("--multi-pod", action="store_true",
                    help="2x16x16 (512 chips) instead of 16x16 (256)")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape x mesh) cell")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced configs (machinery smoke test)")
    ap.add_argument("--unroll", action="store_true",
                    help="unroll the layer stack (full-depth cost pass)")
    ap.add_argument("--cost", action="store_true",
                    help="layer-pair extrapolated cost pass (fast)")
    ap.add_argument("--override", nargs="*", default=[],
                    help="cost pass: ModelConfig overrides, k=v (hillclimb)")
    ap.add_argument("--bf16-gather", action="store_true",
                    help="cost pass: bf16 FSDP all-gathers (hillclimb)")
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--json", help="write the cell report to this path")
    ap.add_argument("--save-hlo", help="dump optimized HLO text to this path")
    ap.add_argument("--out", default="reports/dryrun",
                    help="--all: output directory")
    ap.add_argument("--timeout", type=int, default=3600)
    ap.add_argument("--archs", nargs="*", help="--all: restrict archs")
    ap.add_argument("--shapes", nargs="*", help="--all: restrict shapes")
    args = ap.parse_args()

    if args.all:
        run_all(Path(args.out), reduced=args.reduced, timeout=args.timeout,
                archs=args.archs, shapes=args.shapes)
        return

    if not args.arch or not args.shape:
        ap.error("--arch and --shape required (or --all)")
    overrides = {}
    for kv in (args.override or []):
        k, v = kv.split("=", 1)
        if v.lower() in ("true", "false"):
            v = v.lower() == "true"
        elif v.lstrip("-").isdigit():
            v = int(v)
        overrides[k] = v
    topts = {"bf16_gather": True} if args.bf16_gather else None
    if args.cost:
        rep = run_cost_cell(args.arch, args.shape, reduced=args.reduced,
                            overrides=overrides, train_opts=topts)
    else:
        rep = run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                       reduced=args.reduced, microbatches=args.microbatches,
                       save_hlo=args.save_hlo, unroll=args.unroll,
                       overrides=overrides)
    print_report(rep)
    if args.json:
        Path(args.json).parent.mkdir(parents=True, exist_ok=True)
        Path(args.json).write_text(json.dumps(rep, indent=1))
    sys.exit(0 if rep["ok"] else 1)


if __name__ == "__main__":
    main()
