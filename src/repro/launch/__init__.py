"""Launch layer: production mesh construction, multi-pod dry-run, and the
train/serve drivers. ``dryrun.py`` must be the process entry point when used
(it pins XLA_FLAGS before any jax import)."""
from .mesh import make_production_mesh

__all__ = ["make_production_mesh"]
