"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state, so tests/benches keep their single-CPU world while the
dry-run process (which sets ``xla_force_host_platform_device_count=512``
before importing jax) builds the 256-chip single-pod and 512-chip multi-pod
meshes from the same code path.

Axes:
  * ``pod``   — data-parallel across pods (gradient all-reduce over DCI).
  * ``data``  — in-pod data parallel + FSDP axis.
  * ``model`` — tensor/expert/sequence parallel axis (the TPU analogue of the
                paper's 32-processor SKV array: heads and FFN columns spread
                across it).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    """16x16 = 256 chips/pod; 2 pods = 512 chips when ``multi_pod``."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh_for(devices_per_pod: int, pods: int = 1,
                  model_parallel: int = 16) -> jax.sharding.Mesh:
    """Elastic variant: build a (pods, dp, tp) mesh from whatever device set
    survives a failure — the launcher re-invokes this with the new counts
    (dryrun proves lowering works for both 256- and 512-chip meshes)."""
    dp = devices_per_pod // model_parallel
    if pods > 1:
        return jax.make_mesh((pods, dp, model_parallel),
                             ("pod", "data", "model"))
    return jax.make_mesh((dp, model_parallel), ("data", "model"))


def make_host_mesh(model_parallel: int | None = None) -> jax.sharding.Mesh:
    """Mesh over whatever devices exist in this process (tests, examples)."""
    n = len(jax.devices())
    tp = model_parallel or (2 if n % 2 == 0 and n > 1 else 1)
    return jax.make_mesh((n // tp, tp), ("data", "model"))
