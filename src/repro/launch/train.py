"""Distributed training driver.

Runs the fault-tolerant ``TrainLoop`` under a mesh with the production
sharding rules: FSDP over ``data`` (+ pure DP over ``pod``), TP/EP over
``model``. On this CPU container it runs reduced configs over host devices;
on a real pod the same entry point runs the full config (the dry-run proves
the lowering at 256/512 chips).

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --reduced \
        --steps 50 --seq-len 128 --global-batch 8 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import json
import logging
import time
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.distributed.sharding import (MeshRules, fixup_tree, named,
                                        param_specs)
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.models.api import build_model
from repro.optim import AdamWState, adamw_init
from repro.train import TrainLoop, make_train_step

log = logging.getLogger("repro.launch.train")


def shard_train_state(model, mesh, rng):
    """Init params/opt on-mesh with the production PartitionSpecs."""
    rules = MeshRules(mesh)
    params_shapes = jax.eval_shape(model.init_params, rng)
    pspec = param_specs(params_shapes, rules, train=True)
    pspec = fixup_tree(pspec, params_shapes, mesh)
    p_sh = named(pspec, mesh)
    with mesh:
        params = jax.jit(model.init_params, out_shardings=p_sh)(rng)
        opt = jax.jit(adamw_init,
                      out_shardings=AdamWState(
                          step=named(P(), mesh), mu=p_sh, nu=p_sh))(params)
    return params, opt, pspec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--production-mesh", action="store_true",
                    help="16x16 mesh (needs 256 devices; see dryrun)")
    ap.add_argument("--metrics-out")
    args = ap.parse_args(argv)

    logging.basicConfig(level=logging.INFO)
    cfg = get_config(args.arch, reduced=args.reduced)
    model = build_model(cfg)
    mesh = (make_production_mesh() if args.production_mesh
            else make_host_mesh())
    log.info("mesh: %s", mesh)

    step_fn = make_train_step(model, microbatches=args.microbatches,
                              base_lr=args.lr, total_steps=args.steps)

    with mesh:
        loop = TrainLoop(model, cfg, step_fn, seq_len=args.seq_len,
                         global_batch=args.global_batch,
                         ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every)
        t0 = time.perf_counter()
        history = loop.run(args.steps)
        wall = time.perf_counter() - t0

    tok_s = args.steps * args.seq_len * args.global_batch / wall
    log.info("done: %d steps in %.1fs (%.0f tok/s); final loss %.4f",
             args.steps, wall, tok_s, history[-1]["loss"])
    if args.metrics_out:
        Path(args.metrics_out).write_text(json.dumps(history, indent=1))
    return history


if __name__ == "__main__":
    main()
