"""W4A8 serving-path quantization (paper §IV-B, end to end).

``quantize_params(params)`` walks a params pytree and replaces every
eligible projection weight ``name`` (the wq/wk/wv/wo attention projections
and up/gate/down MLP matrices — the decode step's weight traffic) with the
int4-packed ``name__qp`` + group-scale ``name__qs`` pair that
``layers.linear`` consumes. Stacked layer weights ``[L, K, N]`` quantize per
layer via vmap, so scanned stacks keep their leading axis.

Weight bytes drop 4x vs bf16 (uint8 nibbles + f32 scales at K/128
granularity) — the decode step is weight-read-bound, so this is the
dual-mode-array lever measured in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.quantization import GROUP, quantize_w4

QUANT_KEYS = ("wq", "wk", "wv", "wo", "up", "gate", "down")


def _eligible(name: str, leaf) -> bool:
    return (name in QUANT_KEYS
            and hasattr(leaf, "ndim") and leaf.ndim in (2, 3)
            and leaf.shape[-1] % 2 == 0
            and str(leaf.dtype).startswith(("float", "bfloat")))


def quantize_params(params):
    """Returns a new pytree with eligible projections replaced by
    (packed, scale) pairs. Dicts only (our param trees are nested dicts)."""
    if not isinstance(params, dict):
        return params
    out = {}
    for name, leaf in params.items():
        if isinstance(leaf, dict):
            out[name] = quantize_params(leaf)
            continue
        if _eligible(name, leaf):
            if leaf.ndim == 2:
                qw = quantize_w4(leaf)
            else:  # [L, K, N] stacked layers
                qw = jax.vmap(quantize_w4)(leaf)
            out[name + "__qp"] = qw.packed
            out[name + "__qs"] = qw.scale
        else:
            out[name] = leaf
    return out


def quantized_bytes(params) -> tuple[int, int]:
    """(dense_bytes, quantized_bytes) for the eligible projections."""
    dense = quant = 0
    def walk(d):
        nonlocal dense, quant
        for name, leaf in d.items():
            if isinstance(leaf, dict):
                walk(leaf)
            elif _eligible(name, leaf):
                n = 1
                for dim in leaf.shape:
                    n *= dim
                dense += n * 2                      # bf16
                quant += n // 2 + (n // GROUP) * 4  # nibbles + f32 scales
    walk(params)
    return dense, quant
