"""Shared layer primitives: norms, embeddings, MLPs, parameter init.

Pure-function style: params are nested dicts of arrays; scanned layer stacks
hold arrays with a leading ``[L, ...]`` axis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def maybe_constrain(x: jax.Array, *axes):
    """Sharding constraint against the ambient mesh context; no-op outside
    one (single-device tests). ``axes``: mesh-axis names / tuples / None,
    one per dim. GSPMD pads non-divisible internal values itself."""
    from jax.sharding import PartitionSpec as P
    try:
        return jax.lax.with_sharding_constraint(x, P(*axes))
    except Exception:
        return x


def batch_vocab_constrain(x: jax.Array):
    """Pin a [..., V]-shaped activation to (batch over DP axes, vocab over
    the model axis). The unembed matmul under FSDP leaves V unsharded (the
    'data' axis is claimed by both the batch and the FSDP contraction), which
    materializes a [B, S, V] f32 per chip — 40 GB at 151936-vocab. This one
    constraint is the difference between fitting and not."""
    from repro.distributed.context import get_context
    ctx = get_context()
    if not ctx.active:
        return x
    bd = ctx.batch_axes if x.shape[0] % ctx.axis_size(ctx.batch_axes) == 0 \
        else None
    v_ok = x.shape[-1] % ctx.axis_size(ctx.model_axis) == 0
    axes = (bd, *([None] * (x.ndim - 2)),
            ctx.model_axis if v_ok else None)
    return maybe_constrain(x, *axes)


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * weight.astype(jnp.float32)).astype(dt)


def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32) -> jax.Array:
    scale = (1.0 / d_in) ** 0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


def act_fn(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


def linear(p: dict, name: str, x: jax.Array) -> jax.Array:
    """Projection through params dict ``p``: dense ``p[name]`` or the W4A8
    quantized pair ``p[name+'__qp']`` (int4-packed) / ``p[name+'__qs']``
    (group scales) produced by ``models.quantized.quantize_params`` — the
    paper's dual-mode array (§IV-B): the same call site runs f32/bf16 dense
    or INT4xINT8 GEMV. The quantized leg is backend-aware: on TPU it
    dispatches the Pallas ``kernels/gemv_w4a8`` kernel; elsewhere it runs
    the pure-jnp reference semantics (NOT interpret-mode Pallas, which is
    orders of magnitude too slow for CPU CI) — both compute the identical
    int32-accumulate / group-rescale math, so tests pin them against each
    other rather than against the float matmul."""
    qp = p.get(name + "__qp")
    if qp is None:
        return x @ p[name].astype(x.dtype)
    if jax.default_backend() == "tpu":
        from repro.kernels.gemv_w4a8.ops import gemv_w4a8
        return gemv_w4a8(x, qp, p[name + "__qs"]).astype(x.dtype)
    from repro.core.quantization import QuantizedLinear, w4a8_matmul_ref
    qw = QuantizedLinear(packed=qp, scale=p[name + "__qs"], bias=None)
    return w4a8_matmul_ref(x, qw).astype(x.dtype)


def mlp_init(key, d_model: int, d_ff: int, gated: bool, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 3)
    p = {"up": dense_init(ks[0], d_model, d_ff, dtype),
         "down": dense_init(ks[1], d_ff, d_model, dtype)}
    if gated:
        p["gate"] = dense_init(ks[2], d_model, d_ff, dtype)
    return p


def mlp_apply(p: dict, x: jax.Array, act: str, gated: bool) -> jax.Array:
    up = linear(p, "up", x)
    if gated:
        up = act_fn(act)(linear(p, "gate", x)) * up
    else:
        up = act_fn(act)(up)
    return linear(p, "down", up)
