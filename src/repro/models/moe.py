"""Token-choice top-k MoE with capacity-based scatter dispatch.

Dispatch avoids the GShard ``[tokens, E, C]`` one-hot blowup: position-in-expert
comes from a cumulative sum over the ``[T, E]`` assignment one-hot, tokens
scatter into an ``[E, C, d]`` buffer (expert-parallel: E shards over the
``model`` mesh axis; the scatter/gather is where the all-to-all lives), experts
run as one batched einsum, results gather back with router weights.

Over-capacity tokens drop (standard GShard semantics, ``capacity_factor``
controls head-room); the smoke tests compare against a dense loop-over-experts
reference on under-capacity inputs where the two agree exactly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .layers import act_fn, dense_init


def _maybe_constrain(x: jax.Array, *axes):
    """Sharding constraint against the ambient mesh context. ``axes`` are
    mesh-axis names or None, one per array dim. GSPMD pads non-divisible
    internal values, so no divisibility guard is needed here. No-op on
    meshless (single-device test) traces, where the raw PartitionSpec can't
    resolve."""
    try:
        return jax.lax.with_sharding_constraint(x, P(*axes))
    except Exception:
        return x


def moe_init(key, d_model: int, d_ff: int, n_experts: int, gated: bool = True,
             dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 4)
    def stack(k, din, dout):
        keys = jax.random.split(k, n_experts)
        return jnp.stack([dense_init(kk, din, dout, dtype) for kk in keys])
    p = {
        "router": dense_init(ks[0], d_model, n_experts, dtype),
        "up": stack(ks[1], d_model, d_ff),
        "down": stack(ks[2], d_ff, d_model),
    }
    if gated:
        p["gate"] = stack(ks[3], d_model, d_ff)
    return p


def _route(xf: jax.Array, router: jax.Array, top_k: int):
    """Router: top-k expert ids + renormalized weights + Switch aux loss."""
    e = router.shape[-1]
    logits = xf.astype(jnp.float32) @ router.astype(jnp.float32)   # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, top_k)                     # [T, k]
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)
    assign1 = jax.nn.one_hot(top_e[:, 0], e, dtype=jnp.float32)
    aux = e * jnp.sum(jnp.mean(assign1, axis=0) * jnp.mean(probs, axis=0))
    return top_e, top_w, aux


def _queue_positions(top_e: jax.Array, e: int, c: int):
    """Position of each (token, slot) in its expert queue + keep mask."""
    flat_e = top_e.reshape(-1)                                  # [T*k]
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - 1                        # exclusive count
    pos_in_e = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    keep = pos_in_e < c
    return flat_e, pos_in_e, keep


def _expert_ffn(p: dict, buf: jax.Array, act: str, gated: bool,
                dtype) -> jax.Array:
    """buf: [E?, C, d] -> [E?, C, d] batched expert einsums."""
    up = jnp.einsum("ecd,edf->ecf", buf, p["up"].astype(dtype))
    if gated:
        up = act_fn(act)(jnp.einsum("ecd,edf->ecf", buf,
                                    p["gate"].astype(dtype))) * up
    else:
        up = act_fn(act)(up)
    return jnp.einsum("ecf,efd->ecd", up, p["down"].astype(dtype))


def _dispatch_ffn_combine(p, xf, top_e, top_w, *, e_lo: int, e_loc: int,
                          c: int, top_k: int, act: str, gated: bool):
    """Scatter tokens into the [e_lo, e_lo+e_loc) expert queues, run those
    experts, gather weighted results back to token rows. Pure local math —
    used directly on one device and inside the shard_map EP region (where
    each model shard owns a contiguous expert range)."""
    t, d = xf.shape
    e_total = p["router"].shape[-1]
    flat_e, pos_in_e, keep = _queue_positions(top_e, e_total, c)
    mine = keep & (flat_e >= e_lo) & (flat_e < e_lo + e_loc)
    slot = jnp.where(mine, (flat_e - e_lo) * c + pos_in_e, e_loc * c)

    xe = jnp.repeat(xf, top_k, axis=0) if top_k > 1 else xf     # [T*k, d]
    buf = jnp.zeros((e_loc * c + 1, d), xf.dtype).at[slot].add(xe)
    buf = buf[: e_loc * c].reshape(e_loc, c, d)

    out = _expert_ffn(p, buf, act, gated, xf.dtype)             # [E_loc, C, d]

    out_flat = out.reshape(e_loc * c, d)
    gathered = jnp.where(mine[:, None],
                         out_flat[jnp.minimum(slot, e_loc * c - 1)], 0.0)
    w = top_w.reshape(-1)[:, None].astype(xf.dtype)
    return (gathered * w).reshape(t, top_k, d).sum(axis=1)      # [T, d]


def moe_apply(p: dict, x: jax.Array, *, top_k: int, act: str = "silu",
              gated: bool = True, capacity_factor: float = 1.25,
              capacity: int | None = None) -> tuple[jax.Array, jax.Array]:
    """x: [B, S, d] -> (y: [B, S, d], aux_loss: scalar load-balance loss).

    Under an active DistContext (launchers) this routes to the shard_map EP
    path — GSPMD cannot shard the dispatch scatter ("involuntary full
    rematerialization"), so expert parallelism is explicit: each model shard
    owns E/ep experts, dispatch/FFN/combine run shard-local, and one psum
    over the model axis merges the expert-partial token outputs."""
    from repro.distributed.context import get_context
    ctx = get_context()
    b, s, d = x.shape
    e = p["router"].shape[-1]

    if (ctx.active and capacity is None and ctx.model_axis is not None
            and e % ctx.axis_size(ctx.model_axis) == 0
            and b % ctx.axis_size(ctx.batch_axes) == 0):
        return _moe_apply_ep(p, x, top_k=top_k, act=act, gated=gated,
                             capacity_factor=capacity_factor, ctx=ctx)

    t = b * s
    xf = x.reshape(t, d)
    top_e, top_w, aux = _route(xf, p["router"], top_k)
    c = capacity if capacity is not None else max(
        int(t * top_k / e * capacity_factor), 8)
    y = _dispatch_ffn_combine(p, xf, top_e, top_w, e_lo=0, e_loc=e, c=c,
                              top_k=top_k, act=act, gated=gated)
    return y.reshape(b, s, d), aux


def _moe_apply_ep(p, x, *, top_k, act, gated, capacity_factor, ctx):
    """Expert-parallel MoE via shard_map (see moe_apply docstring).

    Token batch stays sharded over the batch axes; every model shard sees the
    same tokens (router math is replicated — cheap) but scatters/runs only
    its own expert slice; the combine is one psum of [T_loc, d] per layer.
    Capacity is per (data shard, expert): C = T_loc*k/E*cf, which matches the
    global-path capacity in expectation."""
    import jax.sharding as jsh
    P = jsh.PartitionSpec
    b, s, d = x.shape
    e = p["router"].shape[-1]
    ep = ctx.axis_size(ctx.model_axis)
    dp = ctx.axis_size(ctx.batch_axes)
    e_loc = e // ep
    b_loc = b // dp
    t_loc = b_loc * s
    c = max(int(t_loc * top_k / e * capacity_factor), 8)
    bd = ctx.batch_axes if dp > 1 else None

    has_gate = gated and "gate" in p

    def shard_fn(x_s, router, *experts):
        pl = {"router": router, "up": experts[0], "down": experts[1]}
        if has_gate:
            pl["gate"] = experts[2]
        m_idx = jax.lax.axis_index(ctx.model_axis)
        e_lo = m_idx * e_loc
        xf = x_s.reshape(t_loc, d)
        top_e, top_w, aux = _route(xf, router, top_k)
        y = _dispatch_ffn_combine(pl, xf, top_e, top_w, e_lo=e_lo,
                                  e_loc=e_loc, c=c, top_k=top_k, act=act,
                                  gated=gated)
        y = jax.lax.psum(y, ctx.model_axis)       # combine expert partials
        if bd:
            aux = jax.lax.pmean(aux, bd)
        return y.reshape(b_loc, s, d), aux

    espec = P(ctx.model_axis, None, None)
    operands = [x, p["router"], p["up"], p["down"]]
    in_specs = [P(bd, None, None), P(), espec, espec]
    if has_gate:
        operands.append(p["gate"])
        in_specs.append(espec)
    from repro.distributed.shard_map_compat import shard_map
    y, aux = shard_map(
        shard_fn, mesh=ctx.mesh,
        in_specs=tuple(in_specs),
        out_specs=(P(bd, None, None), P()),
        check_vma=False,
    )(*operands)
    return y, aux


def moe_apply_rowwise(p: dict, x: jax.Array, *, top_k: int, act: str = "silu",
                      gated: bool = True) -> tuple[jax.Array, jax.Array]:
    """Capacity-free per-row top-k dispatch: x [T, d] -> (y [T, d], aux).

    Each row dense-gathers its own k expert weight matrices and runs them as
    a [T, k]-batched einsum — no expert queue, no capacity, and therefore no
    cross-row coupling: a row's output depends only on that row. That is the
    property ragged continuous batching needs (per-request equivalence must
    hold while slot membership changes every step — and, under multi-tick
    decode (``TransformerLM.decode_multi``), while rows retire *mid-scan*:
    a parked row's garbage routing can't steal capacity from live rows
    because there is no capacity to steal), and at decode batch sizes
    (T = n_slots) the gather of k·(2-3)·d·d_ff weights is cheaper than
    materializing the [E, C, d] queue buffer. The math matches the capacity
    path exactly whenever that path drops nothing."""
    t, d = x.shape
    top_e, top_w, aux = _route(x, p["router"], top_k)           # [T, k]
    up = jnp.einsum("td,tkdf->tkf", x, p["up"][top_e].astype(x.dtype))
    if gated:
        up = act_fn(act)(jnp.einsum("td,tkdf->tkf", x,
                                    p["gate"][top_e].astype(x.dtype))) * up
    else:
        up = act_fn(act)(up)
    y = jnp.einsum("tkf,tkfd->tkd", up, p["down"][top_e].astype(x.dtype))
    y = (y * top_w[..., None].astype(x.dtype)).sum(axis=1)
    return y, aux


def moe_apply_dense_ref(p: dict, x: jax.Array, *, top_k: int, act: str = "silu",
                        gated: bool = True) -> jax.Array:
    """Dense loop-over-experts oracle (no capacity drops). Test-only."""
    b, s, d = x.shape
    e = p["router"].shape[-1]
    xf = x.reshape(-1, d)
    logits = xf.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, top_k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)
    y = jnp.zeros_like(xf)
    for ei in range(e):
        up = xf @ p["up"][ei]
        if gated:
            up = act_fn(act)(xf @ p["gate"][ei]) * up
        else:
            up = act_fn(act)(up)
        oi = up @ p["down"][ei]
        wi = jnp.sum(jnp.where(top_e == ei, top_w, 0.0), axis=-1)[:, None]
        y = y + oi * wi.astype(x.dtype)
    return y.reshape(b, s, d)
