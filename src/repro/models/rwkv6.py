"""RWKV6 ("Finch") — attention-free arch with data-dependent decay.

The paper's SwiftKV attention is inapplicable here (no KV cache, no softmax —
DESIGN.md §4); the WKV recurrence is itself a per-token single-pass state
update, so decode is O(1) in context length and the 500k-decode shape runs.

Simplifications vs the full release (documented): static token-shift mix
coefficients (Finch's data-dependent lerp reduced to the RWKV5 form); the
data-dependent decay ``w_t`` — the signature RWKV6 feature — is kept, via a
low-rank projection. Head layout: [H, N] with N = rwkv_head_dim.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .layers import dense_init, linear, rms_norm


class RWKVLayerState(NamedTuple):
    x_prev_att: jax.Array  # [B, d]
    x_prev_ffn: jax.Array  # [B, d]
    wkv: jax.Array         # [B, H, N, N] (key-dim x value-dim)


def rwkv_layer_init(key, d_model: int, d_ff: int, head_dim: int,
                    dtype=jnp.float32) -> dict:
    h = d_model // head_dim
    ks = jax.random.split(key, 12)
    lr = max(32, d_model // 16)  # low-rank width for the decay projection
    return {
        # time mix
        "mix_rkvwg": jnp.full((5, d_model), 0.5, dtype),
        "wr": dense_init(ks[0], d_model, d_model, dtype),
        "wk": dense_init(ks[1], d_model, d_model, dtype),
        "wv": dense_init(ks[2], d_model, d_model, dtype),
        "wg": dense_init(ks[3], d_model, d_model, dtype),
        "wo": dense_init(ks[4], d_model, d_model, dtype),
        "w0": jnp.full((d_model,), -6.0, dtype),              # base decay
        "w_a": dense_init(ks[5], d_model, lr, dtype),          # low-rank dd-decay
        "w_b": dense_init(ks[6], lr, d_model, dtype),
        "u": jnp.zeros((h, head_dim), dtype),                  # current-token bonus
        "ln_x": jnp.ones((d_model,), dtype),                   # per-head groupnorm
        # channel mix
        "mix_ffn": jnp.full((2, d_model), 0.5, dtype),
        "fk": dense_init(ks[7], d_model, d_ff, dtype),
        "fv": dense_init(ks[8], d_ff, d_model, dtype),
        "fr": dense_init(ks[9], d_model, d_model, dtype),
    }


def _decay(p, xw):
    lr = jnp.tanh(xw @ p["w_a"]) @ p["w_b"]
    return jnp.exp(-jnp.exp((p["w0"] + lr).astype(jnp.float32)))  # (0,1) per chan


def _wkv_step(s, r, k, v, w, u):
    """One WKV step per head. s: [N, N]; r,k,w,u: [N]; v: [N]."""
    kv = k[:, None] * v[None, :]                               # [N, N]
    y = jnp.einsum("n,nm->m", r, s + u[:, None] * kv)
    s_new = w[:, None] * s + kv
    return s_new, y


def rwkv_time_mix(p: dict, x: jax.Array, state: RWKVLayerState,
                  head_dim: int, n_valid: jax.Array | None = None
                  ) -> tuple[jax.Array, RWKVLayerState]:
    """x: [B, S, d] -> (y, new state). Single pass over S via lax.scan,
    seeded from ``state`` (zero state == from-scratch prefill; a non-zero
    state continues a chunked prefill mid-prompt).

    ``n_valid``: optional scalar — positions >= n_valid are padding and must
    be exact state no-ops (k=0 kills the kv update, w=1 keeps the decay
    identity, and the token-shift carry snapshots position n_valid-1), so a
    right-padded final chunk leaves the same state as an unpadded one."""
    b, s, d = x.shape
    dt = x.dtype
    h = d // head_dim
    x_prev = jnp.concatenate([state.x_prev_att[:, None, :], x[:, :-1, :]], axis=1)
    mix = p["mix_rkvwg"].astype(dt)                           # [5, d]
    def lerp(i):
        return x * mix[i] + x_prev * (1 - mix[i])
    xr, xk, xv, xw, xg = (lerp(i) for i in range(5))
    # layers.linear: dense or the W4A8 pair — wk/wv/wo are quantized under
    # +w4a8 serving (QUANT_KEYS), wr/wg fall through dense
    r = linear(p, "wr", xr).astype(dt).reshape(b, s, h, head_dim)
    k = linear(p, "wk", xk).astype(dt).reshape(b, s, h, head_dim)
    v = linear(p, "wv", xv).astype(dt).reshape(b, s, h, head_dim)
    g = jax.nn.silu(linear(p, "wg", xg).astype(dt))
    w = _decay(p, xw).reshape(b, s, h, head_dim)              # f32
    if n_valid is not None:
        valid = (jnp.arange(s) < n_valid)[None, :, None, None]
        k = jnp.where(valid, k, 0.0)
        w = jnp.where(valid, w, 1.0)

    # chunked WKV scan: the inner per-token recurrence is rematted per chunk,
    # so backward stores one wkv state per chunk boundary instead of one per
    # token (4096-step scans otherwise save ~GBs of [B,H,N,N] carries/layer)
    chunk = min(64, s)
    pad = (-s) % chunk
    n_chunks = (s + pad) // chunk

    def pad_chunk(a, fill=0.0):
        if pad:
            a = jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)),
                        constant_values=fill)
        return a.reshape(b, n_chunks, chunk, h, head_dim)

    rc = pad_chunk(r.astype(jnp.float32))
    kc = pad_chunk(k.astype(jnp.float32))   # k=0 on pads: kv update is 0
    vc = pad_chunk(v.astype(jnp.float32))
    wc = pad_chunk(w, fill=1.0)             # w=1 on pads: state unchanged

    def scan_batch(rb, kb, vb, wb, s0):
        def step(sh, inp):
            r_t, k_t, v_t, w_t = inp                           # [h, N] each
            s_new, y = jax.vmap(_wkv_step)(sh, r_t, k_t, v_t, w_t,
                                           p["u"].astype(jnp.float32))
            return s_new, y

        def chunk_step(sh, inp):
            return jax.lax.scan(step, sh, inp)

        s_fin, ys = jax.lax.scan(jax.checkpoint(chunk_step), s0,
                                 (rb, kb, vb, wb))
        return s_fin, ys.reshape(n_chunks * chunk, h, head_dim)[:s]

    s_fin, ys = jax.vmap(scan_batch)(rc, kc, vc, wc,
                                     state.wkv.astype(jnp.float32))
    y = ys.reshape(b, s, d).astype(dt)
    y = rms_norm(y, p["ln_x"]) * g
    y = linear(p, "wo", y).astype(dt)
    x_last = (x[:, -1, :] if n_valid is None else
              jax.lax.dynamic_slice_in_dim(x, n_valid - 1, 1, axis=1)[:, 0])
    new_state = RWKVLayerState(x_prev_att=x_last, x_prev_ffn=state.x_prev_ffn,
                               wkv=s_fin)
    return y, new_state


def rwkv_channel_mix(p: dict, x: jax.Array, state: RWKVLayerState,
                     n_valid: jax.Array | None = None
                     ) -> tuple[jax.Array, RWKVLayerState]:
    dt = x.dtype
    x_prev = jnp.concatenate([state.x_prev_ffn[:, None, :], x[:, :-1, :]], axis=1)
    mix = p["mix_ffn"].astype(dt)
    xk = x * mix[0] + x_prev * (1 - mix[0])
    xr = x * mix[1] + x_prev * (1 - mix[1])
    k = jnp.square(jax.nn.relu(xk @ p["fk"].astype(dt)))
    out = jax.nn.sigmoid(xr @ p["fr"].astype(dt)) * (k @ p["fv"].astype(dt))
    x_last = (x[:, -1, :] if n_valid is None else
              jax.lax.dynamic_slice_in_dim(x, n_valid - 1, 1, axis=1)[:, 0])
    return out, state._replace(x_prev_ffn=x_last)


def rwkv_time_mix_step(p: dict, x_t: jax.Array, state: RWKVLayerState,
                       head_dim: int, active: jax.Array | None = None
                       ) -> tuple[jax.Array, RWKVLayerState]:
    """Decode: x_t [B, d] one token, O(1) state update.

    ``active``: optional [B] bool ragged-batch mask — inactive rows are
    exact state no-ops (their x_prev / wkv carry through unchanged), the
    invariant multi-tick decode (``TransformerLM.decode_multi``) relies on
    when a row retires mid-scan."""
    b, d = x_t.shape
    dt = x_t.dtype
    h = d // head_dim
    mix = p["mix_rkvwg"].astype(dt)
    xp = state.x_prev_att
    def lerp(i):
        return x_t * mix[i] + xp * (1 - mix[i])
    xr, xk, xv, xw, xg = (lerp(i) for i in range(5))
    r = linear(p, "wr", xr).astype(jnp.float32).reshape(b, h, head_dim)
    k = linear(p, "wk", xk).astype(jnp.float32).reshape(b, h, head_dim)
    v = linear(p, "wv", xv).astype(jnp.float32).reshape(b, h, head_dim)
    g = jax.nn.silu(linear(p, "wg", xg).astype(dt))
    w = _decay(p, xw).reshape(b, h, head_dim)
    s_new, y = jax.vmap(jax.vmap(_wkv_step))(
        state.wkv, r, k, v, w, jnp.broadcast_to(p["u"].astype(jnp.float32),
                                                (b, h, head_dim)))
    y = y.reshape(b, d).astype(dt)
    y = rms_norm(y, p["ln_x"]) * g
    att_new, wkv_new = x_t, s_new
    if active is not None:
        att_new = jnp.where(active[:, None], att_new, state.x_prev_att)
        wkv_new = jnp.where(active[:, None, None, None], wkv_new, state.wkv)
    return linear(p, "wo", y).astype(dt), state._replace(x_prev_att=att_new,
                                                         wkv=wkv_new)


def rwkv_channel_mix_step(p: dict, x_t: jax.Array, state: RWKVLayerState,
                          active: jax.Array | None = None
                          ) -> tuple[jax.Array, RWKVLayerState]:
    """``active``: see :func:`rwkv_time_mix_step` — inactive rows keep their
    x_prev_ffn carry unchanged."""
    dt = x_t.dtype
    mix = p["mix_ffn"].astype(dt)
    xp = state.x_prev_ffn
    xk = x_t * mix[0] + xp * (1 - mix[0])
    xr = x_t * mix[1] + xp * (1 - mix[1])
    k = jnp.square(jax.nn.relu(xk @ p["fk"].astype(dt)))
    out = jax.nn.sigmoid(xr @ p["fr"].astype(dt)) * (k @ p["fv"].astype(dt))
    ffn_new = (x_t if active is None
               else jnp.where(active[:, None], x_t, state.x_prev_ffn))
    return out, state._replace(x_prev_ffn=ffn_new)
