from .api import build_model, input_specs, lm_loss, needs_source, source_spec
from .config import SHAPES, ModelConfig, ShapeSpec, shape_applicable
from .transformer import TransformerLM
from .whisper import WhisperModel

__all__ = ["build_model", "input_specs", "lm_loss", "needs_source",
           "source_spec", "SHAPES", "ModelConfig", "ShapeSpec",
           "shape_applicable", "TransformerLM", "WhisperModel"]
