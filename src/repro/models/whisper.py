"""Whisper-style encoder-decoder backbone (audio family).

The conv/mel frontend is a STUB per the assignment: ``source`` inputs are
precomputed frame embeddings ``[B, source_len, d_model]``. The encoder is a
bidirectional TransformerLM stack; the decoder is causal with in-layer
cross-attention (``cross_attn_every=1``), its cross-KV computed once at
prefill and cached — decode then touches only the decoder stack.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .transformer import Cache, Params, TransformerLM


class WhisperModel:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        enc_cfg = cfg.replace(family="dense", cross_attn_every=0,
                              n_layers=cfg.encoder_layers, window=None)
        dec_cfg = cfg.replace(family="dense", cross_attn_every=1)
        self.encoder = TransformerLM(enc_cfg, causal=False, with_embedding=False)
        self.decoder = TransformerLM(dec_cfg)

    def init_params(self, rng) -> Params:
        k1, k2 = jax.random.split(rng)
        return {"encoder": self.encoder.init_params(k1),
                "decoder": self.decoder.init_params(k2)}

    def encode(self, params: Params, source: jax.Array,
               remat: bool = True) -> jax.Array:
        h, _ = self.encoder.forward(params["encoder"], embeds=source,
                                    remat=remat)
        return h

    def forward(self, params: Params, tokens: jax.Array, *,
                source: jax.Array, remat: bool = True):
        enc = self.encode(params, source, remat)
        return self.decoder.forward(params["decoder"], tokens, source=enc,
                                    remat=remat)

    def init_cache(self, batch: int, max_len: int,
                   source_len: int | None = None) -> Cache:
        return self.decoder.init_cache(batch, max_len,
                                       source_len or self.cfg.source_len)

    def prefill(self, params: Params, tokens: jax.Array, cache: Cache,
                source: jax.Array | None = None):
        enc = self.encode(params, source)
        return self.decoder.prefill(params["decoder"], tokens, cache, source=enc)

    def decode_step(self, params: Params, tokens: jax.Array, cache: Cache):
        return self.decoder.decode_step(params["decoder"], tokens, cache)
