"""Whisper-style encoder-decoder backbone (audio family).

The conv/mel frontend is a STUB per the assignment: ``source`` inputs are
precomputed frame embeddings ``[B, source_len, d_model]``. The encoder is a
bidirectional TransformerLM stack; the decoder is causal with in-layer
cross-attention (``cross_attn_every=1``), its cross-KV computed once at
prefill and cached — decode then touches only the decoder stack.

Serving: the model is a thin delegator — every serving entry point
(``prefill_chunk`` / ``prefill_chunks_batched`` / ``decode_step`` /
``decode_multi`` / ``finalize_slot`` / ``release_slot`` and the source-KV
pool trio ``ingest_source`` / ``assign_source`` / ``release_source``)
forwards to the decoder stack with ``params["decoder"]``, so the
continuous-batching engine drives an encoder-decoder model through exactly
the same calls as a decoder-only one. The single encoder-decoder-specific
step is :meth:`ingest_source`: it runs the (length-masked) encoder over the
padded frame embeddings *before* projecting the decoder's per-layer cross
K/V into the pool entry — one encoder pass per distinct source id, shared
by every request that presents the same id.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .transformer import Cache, Params, TransformerLM


class WhisperModel:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        enc_cfg = cfg.replace(family="dense", cross_attn_every=0,
                              n_layers=cfg.encoder_layers, window=None)
        dec_cfg = cfg.replace(family="dense", cross_attn_every=1)
        self.encoder = TransformerLM(enc_cfg, causal=False, with_embedding=False)
        self.decoder = TransformerLM(dec_cfg)

    def init_params(self, rng) -> Params:
        k1, k2 = jax.random.split(rng)
        return {"encoder": self.encoder.init_params(k1),
                "decoder": self.decoder.init_params(k2)}

    def encode(self, params: Params, source: jax.Array,
               remat: bool = True,
               source_len: jax.Array | None = None) -> jax.Array:
        """``source_len``: optional [B] valid frame prefixes — a padded
        batch masks encoder self-attention keys past each row's true
        length, so valid positions' encodings are independent of the
        padding (the bidirectional analogue of causal masking)."""
        h, _ = self.encoder.forward(params["encoder"], embeds=source,
                                    kv_length=source_len, remat=remat)
        return h

    def forward(self, params: Params, tokens: jax.Array, *,
                source: jax.Array, remat: bool = True):
        enc = self.encode(params, source, remat)
        return self.decoder.forward(params["decoder"], tokens, source=enc,
                                    remat=remat)

    def init_cache(self, batch: int, max_len: int,
                   source_len: int | None = None, *,
                   n_sources: int | None = None,
                   chunk: int | None = None,
                   kv_dtype=None) -> Cache:
        return self.decoder.init_cache(batch, max_len,
                                       source_len or self.cfg.source_len,
                                       n_sources=n_sources, chunk=chunk,
                                       kv_dtype=kv_dtype)

    def prefill(self, params: Params, tokens: jax.Array, cache: Cache,
                source: jax.Array | None = None,
                source_len: jax.Array | None = None):
        if source is None:
            return self.decoder.prefill(params["decoder"], tokens, cache)
        enc = self.encode(params, source, source_len=source_len)
        return self.decoder.prefill(params["decoder"], tokens, cache,
                                    source=enc, source_len=source_len)

    def decode_step(self, params: Params, tokens: jax.Array, cache: Cache,
                    active: jax.Array | None = None):
        return self.decoder.decode_step(params["decoder"], tokens, cache,
                                        active)

    def decode_multi(self, params: Params, *args, **kw):
        return self.decoder.decode_multi(params["decoder"], *args, **kw)

    # ---- continuous serving (delegated to the decoder stack) --------------
    def supports_ragged_serving(self) -> bool:
        return self.decoder.supports_ragged_serving()

    def prefill_chunk(self, params: Params, *args, **kw):
        return self.decoder.prefill_chunk(params["decoder"], *args, **kw)

    def prefill_chunks_batched(self, params: Params, *args, **kw):
        return self.decoder.prefill_chunks_batched(params["decoder"],
                                                   *args, **kw)

    def finalize_slot(self, cache: Cache, slot, length) -> Cache:
        return self.decoder.finalize_slot(cache, slot, length)

    def release_slot(self, cache: Cache, slot) -> Cache:
        return self.decoder.release_slot(cache, slot)

    def ingest_source(self, params: Params, source: jax.Array, cache: Cache,
                      entry, length) -> Cache:
        """Encoder-decoder source ingest: run the length-masked encoder
        over the padded frames once, then pool the decoder's per-layer
        cross K/V of the encoding (``TransformerLM.ingest_source``)."""
        enc = self.encode(params, source[None],
                          source_len=jnp.reshape(length, (1,)))[0]
        return self.decoder.ingest_source(params["decoder"], enc, cache,
                                          entry, length)

    def assign_source(self, cache: Cache, slot, entry) -> Cache:
        return self.decoder.assign_source(cache, slot, entry)

    def release_source(self, cache: Cache, entry) -> Cache:
        return self.decoder.release_source(cache, entry)
