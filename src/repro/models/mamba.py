"""Selective SSM (Mamba-style) branch — used by the hymba hybrid arch.

Structure per block: in-proj -> causal depthwise conv -> SiLU -> selective
scan (data-dependent dt, B, C; diagonal A) -> gate -> out-proj. Decode carries
an O(1) state: (conv tail, ssm state) — no KV cache, which is why the hybrid
arch runs the 500k-context decode shape.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .layers import dense_init


class MambaState(NamedTuple):
    conv: jax.Array  # [B, K-1, d_inner] trailing inputs for the causal conv
    ssm: jax.Array   # [B, d_inner, N] hidden state


def mamba_init(key, d_model: int, *, state: int = 16, conv: int = 4,
               expand: int = 2, dtype=jnp.float32) -> dict:
    d_inner = expand * d_model
    ks = jax.random.split(key, 7)
    return {
        "in_proj": dense_init(ks[0], d_model, 2 * d_inner, dtype),
        "conv_w": (jax.random.normal(ks[1], (conv, d_inner), jnp.float32)
                   * (1.0 / conv) ** 0.5).astype(dtype),
        "x_proj": dense_init(ks[2], d_inner, 1 + 2 * state, dtype),  # dt, B, C
        "dt_bias": jnp.zeros((d_inner,), dtype),
        "dt_w": dense_init(ks[3], 1, d_inner, dtype)[0],             # dt broadcast
        "a_log": jnp.log(jnp.tile(jnp.arange(1, state + 1, dtype=jnp.float32),
                                  (d_inner, 1))).astype(dtype),      # [d_inner, N]
        "d_skip": jnp.ones((d_inner,), dtype),
        "out_proj": dense_init(ks[4], d_inner, d_model, dtype),
    }


def _ssm_step(params, h, x_t, dt_t, b_t, c_t):
    """One selective-scan step. h: [d_inner, N]; x_t: [d_inner];
    dt_t: [d_inner]; b_t, c_t: [N]."""
    a = -jnp.exp(params["a_log"].astype(jnp.float32))        # [d_inner, N]
    da = jnp.exp(dt_t[:, None] * a)                          # discretized decay
    dbx = (dt_t * x_t)[:, None] * b_t[None, :]               # [d_inner, N]
    h_new = da * h + dbx
    y = jnp.einsum("dn,n->d", h_new, c_t)
    return h_new, y


def _conv_mix(conv_w, x_window):
    """x_window: [K, d_inner] -> [d_inner] causal depthwise conv output."""
    return jnp.sum(conv_w * x_window, axis=0)


def mamba_forward(params: dict, x: jax.Array,
                  return_state: bool = False,
                  state: MambaState | None = None,
                  n_valid: jax.Array | None = None):
    """x: [B, S, d_model] -> [B, S, d_model] (training / prefill path).
    ``return_state``: also return the MambaState after the last position.

    ``state``: continue from an earlier chunk's state instead of zeros — the
    conv tail replaces the causal zero-padding and the SSM scan seeds from
    ``state.ssm`` (chunked slot prefill). ``n_valid``: positions >= n_valid
    are padding and must be exact state no-ops (dt=0 makes the discretized
    decay da=exp(0)=1 and the input term 0; the returned conv tail is the
    last K-1 *valid* inputs)."""
    b, s, d = x.shape
    dt_x = x.dtype
    d_inner = params["out_proj"].shape[0]
    k = params["conv_w"].shape[0]
    xz = x @ params["in_proj"].astype(dt_x)
    xi, z = jnp.split(xz, 2, axis=-1)                         # [B, S, d_inner]

    # causal depthwise conv along S; a carried state supplies the K-1 inputs
    # preceding this chunk in place of the zero pad
    if state is not None and k > 1:
        xi_pad = jnp.concatenate([state.conv.astype(dt_x), xi], axis=1)
    else:
        xi_pad = jnp.pad(xi, ((0, 0), (k - 1, 0), (0, 0)))
    conv = sum(xi_pad[:, i:i + s, :] * params["conv_w"][i].astype(dt_x)
               for i in range(k))
    u = jax.nn.silu(conv)

    dbc = u @ params["x_proj"].astype(dt_x)                   # [B, S, 1+2N]
    n = (dbc.shape[-1] - 1) // 2
    dt = jax.nn.softplus(dbc[..., :1].astype(jnp.float32) * params["dt_w"]
                         + params["dt_bias"])
    if n_valid is not None:
        dt = dt * (jnp.arange(s) < n_valid)[None, :, None]
    bmat, cmat = dbc[..., 1:1 + n], dbc[..., 1 + n:]

    def scan_one(carry, inp):
        u_t, dt_t, b_t, c_t = inp
        h, y = _ssm_step(params, carry, u_t.astype(jnp.float32),
                         dt_t.astype(jnp.float32), b_t.astype(jnp.float32),
                         c_t.astype(jnp.float32))
        return h, y

    def per_batch(u_b, dt_b, b_b, c_b, h0):
        h_fin, ys = jax.lax.scan(scan_one, h0, (u_b, dt_b, b_b, c_b))
        return h_fin, ys                                      # [S, d_inner]

    h0s = (jnp.zeros((b, d_inner, n), jnp.float32) if state is None
           else state.ssm.astype(jnp.float32))
    h_fin, ys = jax.vmap(per_batch)(u, dt, bmat, cmat, h0s)
    ys = ys.astype(dt_x)
    y = ys + u * params["d_skip"].astype(dt_x)
    y = y * jax.nn.silu(z)
    out = y @ params["out_proj"].astype(dt_x)
    if return_state:
        # conv tail: last K-1 pre-conv inputs preceding the position after
        # the final valid token (from the padded/carried stream)
        if k <= 1:
            tail = xi[:, :0, :]
        elif n_valid is None:
            tail = xi_pad[:, -(k - 1):, :]
        else:
            tail = jax.lax.dynamic_slice_in_dim(xi_pad, n_valid, k - 1, axis=1)
        return out, MambaState(conv=tail.astype(jnp.float32), ssm=h_fin)
    return out


def mamba_init_state(params: dict, batch: int) -> MambaState:
    d_inner = params["out_proj"].shape[0]
    k = params["conv_w"].shape[0]
    n = (params["x_proj"].shape[1] - 1) // 2
    return MambaState(conv=jnp.zeros((batch, k - 1, d_inner), jnp.float32),
                      ssm=jnp.zeros((batch, d_inner, n), jnp.float32))


def mamba_decode_step(params: dict, x_t: jax.Array, state: MambaState,
                      active: jax.Array | None = None
                      ) -> tuple[jax.Array, MambaState]:
    """x_t: [B, d_model] one token -> ([B, d_model], new state).

    ``active``: optional [B] bool ragged-batch mask — inactive rows carry
    their (conv, ssm) state through unchanged (there is no "parking row"
    for a recurrent state: the row itself *is* the state). Masking here,
    at the state-update site, is what lets multi-tick decode
    (``TransformerLM.decode_multi``) flip a row inactive mid-scan without
    corrupting the state it hands to the slot's next occupant check."""
    dt_x = x_t.dtype
    xz = x_t @ params["in_proj"].astype(dt_x)
    xi, z = jnp.split(xz, 2, axis=-1)                         # [B, d_inner]
    window = jnp.concatenate([state.conv, xi[:, None, :].astype(jnp.float32)], axis=1)
    conv = jnp.einsum("bkd,kd->bd", window, params["conv_w"].astype(jnp.float32))
    u = jax.nn.silu(conv).astype(dt_x)

    dbc = u @ params["x_proj"].astype(dt_x)
    n = (dbc.shape[-1] - 1) // 2
    dt = jax.nn.softplus(dbc[..., :1].astype(jnp.float32) * params["dt_w"]
                         + params["dt_bias"])
    bvec, cvec = dbc[..., 1:1 + n], dbc[..., 1 + n:]

    h, y = jax.vmap(lambda hh, uu, dd, bb, cc: _ssm_step(params, hh, uu, dd, bb, cc))(
        state.ssm, u.astype(jnp.float32), dt.astype(jnp.float32),
        bvec.astype(jnp.float32), cvec.astype(jnp.float32))
    y = y.astype(dt_x) + u * params["d_skip"].astype(dt_x)
    y = y * jax.nn.silu(z)
    conv_new, ssm_new = window[:, 1:], h
    if active is not None:
        m3 = active[:, None, None]
        conv_new = jnp.where(m3, conv_new, state.conv)
        ssm_new = jnp.where(m3, ssm_new, state.ssm)
    return y @ params["out_proj"].astype(dt_x), MambaState(conv=conv_new,
                                                           ssm=ssm_new)
