"""Generic decoder-only transformer covering the dense / moe / vlm / hybrid
families (whisper composes two of these stacks — see whisper.py).

Layer stacks are ``lax.scan``s over parameter pytrees with a leading ``[L]``
axis, so 100-layer configs lower to compact HLO. VLM-style dedicated
cross-attention layers (every Nth layer) scan over *groups* of
``(cross_attn_every - 1) self + 1 cross`` layers.

Decode (the paper's workload) maintains a KV cache ``[L, B, Smax, Hkv, Dh]``;
keys are cached *post-RoPE* (paper §IV-C) and the query/key rotation for the
new token uses the incremental Eq. 11 recurrence carried in the cache
(``rope_mode="incremental"``) or direct cos/sin (``"direct"``).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import attention as attn_lib
from repro.core import rope as rope_lib
from repro.core.quantization import quantize_kv
from .config import ModelConfig
from .layers import (batch_vocab_constrain, dense_init, embed_init, linear,
                     mlp_apply, mlp_init, rms_norm)
from . import mamba as mamba_lib
from . import moe as moe_lib
from . import rwkv6 as rwkv_lib

Params = dict[str, Any]
Cache = dict[str, Any]


def seeded_gumbel_pick(rng_key: jax.Array, logits: jax.Array,
                       serial: jax.Array, token_idx: jax.Array,
                       temperature: float) -> jax.Array:
    """One exact softmax(logits/temperature) draw as Gumbel-max, keyed on
    ``(rng_key, serial, token_idx)`` — request-intrinsic, so the draw for a
    request's token i cannot depend on batch composition, scheduling, or
    the decode tick horizon. The single definition is shared by the fused
    multi-tick decode (:meth:`TransformerLM.decode_multi`, tokens 1..n) and
    the serving engine's prefill first-token pick (token 0): both sides of
    a request's stream MUST come from this one key derivation."""
    key = jax.random.fold_in(jax.random.fold_in(rng_key, serial), token_idx)
    g = jax.random.gumbel(key, logits.shape, logits.dtype)
    return jnp.argmax(logits / temperature + g).astype(jnp.int32)


def make_remat(cfg: ModelConfig):
    """Layer-boundary rematerialization with a configurable policy:
    'full' recomputes everything (min memory), 'dots' saves matmul outputs
    (halves the recompute FLOPs/bytes at higher live memory)."""
    if cfg.remat_policy == "dots":
        pol = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return lambda f: jax.checkpoint(f, policy=pol)
    return jax.checkpoint


def layer_scan(step, carry, xs, *, unroll: bool):
    """``lax.scan`` over a stacked-layer pytree, or a Python unroll.

    Unrolling exists for the dry-run cost model: XLA's ``cost_analysis``
    counts a while-loop body once, so scanned stacks under-report FLOPs /
    bytes / collective traffic by a factor of L. Runtime paths keep the scan
    (compact HLO); the dry-run lowers with ``cfg.unroll_layers=True``.
    """
    if not unroll:
        return jax.lax.scan(step, carry, xs)
    n = jax.tree_util.tree_leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        x_i = jax.tree.map(lambda a: a[i], xs)
        carry, y = step(carry, x_i)
        ys.append(y)
    if ys and ys[0] is not None:
        stacked = jax.tree.map(lambda *zs: jnp.stack(zs), *ys)
    else:
        stacked = None
    return carry, stacked


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _attn_init(key, cfg: ModelConfig, cross: bool = False) -> Params:
    d, dh = cfg.d_model, cfg.resolved_head_dim
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, hq * dh),
        "wk": dense_init(ks[1], d, hkv * dh),
        "wv": dense_init(ks[2], d, hkv * dh),
        "wo": dense_init(ks[3], hq * dh, d),
    }
    if cfg.qk_norm:
        p["qn"] = jnp.ones((dh,), jnp.float32)
        p["kn"] = jnp.ones((dh,), jnp.float32)
    if cross:
        p["gate"] = jnp.zeros((), jnp.float32)  # gated cross-attn (llama-vision)
    return p


def _ffn_init(key, cfg: ModelConfig) -> Params:
    if cfg.n_experts:
        return moe_lib.moe_init(key, cfg.d_model, cfg.d_ff, cfg.n_experts,
                                gated=cfg.gated_mlp)
    return mlp_init(key, cfg.d_model, cfg.d_ff, cfg.gated_mlp)


def _self_block_init(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 4)
    p = {
        "ln1": jnp.ones((cfg.d_model,), jnp.float32),
        "attn": _attn_init(ks[0], cfg),
        "ln2": jnp.ones((cfg.d_model,), jnp.float32),
        "ffn": _ffn_init(ks[1], cfg),
    }
    if cfg.family == "hybrid":
        p["mamba"] = mamba_lib.mamba_init(ks[2], cfg.d_model, state=cfg.ssm_state,
                                          conv=cfg.ssm_conv, expand=cfg.ssm_expand)
        p["ln_attn_out"] = jnp.ones((cfg.d_model,), jnp.float32)
        p["ln_mamba_out"] = jnp.ones((cfg.d_model,), jnp.float32)
    if cfg.cross_attn_every == 1:   # whisper-style: cross-attn inside the layer
        p["ln_cross"] = jnp.ones((cfg.d_model,), jnp.float32)
        p["cross"] = _attn_init(ks[3], cfg, cross=True)
    return p


def _cross_block_init(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 2)
    return {
        "ln1": jnp.ones((cfg.d_model,), jnp.float32),
        "cross": _attn_init(ks[0], cfg, cross=True),
        "ln2": jnp.ones((cfg.d_model,), jnp.float32),
        "ffn": mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.gated_mlp),
    }


class TransformerLM:
    """cfg.family in {dense, moe, hybrid, vlm, ssm}. ``ssm`` -> RWKV6 stack."""

    def __init__(self, cfg: ModelConfig, *, causal: bool = True,
                 with_embedding: bool = True):
        self.cfg = cfg
        self.causal = causal
        self.with_embedding = with_embedding

    # ---- init ------------------------------------------------------------
    def init_params(self, rng) -> Params:
        cfg = self.cfg
        k_embed, k_blocks, k_cross, k_out = jax.random.split(rng, 4)
        params: Params = {"ln_f": jnp.ones((cfg.d_model,), jnp.float32)}
        if self.with_embedding:
            params["embed"] = embed_init(k_embed, cfg.vocab_size, cfg.d_model)
            if not cfg.tie_embeddings:
                params["unembed"] = dense_init(k_out, cfg.d_model, cfg.vocab_size)

        if cfg.family == "ssm":
            keys = jax.random.split(k_blocks, cfg.n_layers)
            params["blocks"] = jax.vmap(
                lambda k: {"ln1": jnp.ones((cfg.d_model,), jnp.float32),
                           "ln2": jnp.ones((cfg.d_model,), jnp.float32),
                           "mix": rwkv_lib.rwkv_layer_init(
                               k, cfg.d_model, cfg.d_ff, cfg.rwkv_head_dim)})(keys)
            return params

        n_cross = self._n_cross_groups()
        n_self = cfg.n_layers - n_cross
        keys = jax.random.split(k_blocks, n_self)
        params["blocks"] = jax.vmap(lambda k: _self_block_init(k, cfg))(keys)
        if n_cross:
            ckeys = jax.random.split(k_cross, n_cross)
            params["cross_blocks"] = jax.vmap(
                lambda k: _cross_block_init(k, cfg))(ckeys)
        return params

    def _n_cross_groups(self) -> int:
        cfg = self.cfg
        if cfg.cross_attn_every > 1:          # vlm: dedicated cross layers
            return cfg.n_layers // cfg.cross_attn_every
        return 0

    # ---- shared attention math --------------------------------------------
    def _qkv(self, p: Params, x: jax.Array):
        cfg = self.cfg
        dh = cfg.resolved_head_dim
        b, s, _ = x.shape
        q = linear(p, "wq", x).reshape(b, s, cfg.n_heads, dh)
        k = linear(p, "wk", x).reshape(b, s, cfg.n_kv_heads, dh)
        v = linear(p, "wv", x).reshape(b, s, cfg.n_kv_heads, dh)
        if cfg.qk_norm:
            q = rms_norm(q, p["qn"], cfg.norm_eps)
            k = rms_norm(k, p["kn"], cfg.norm_eps)
        return q, k, v

    def _qkv_rope(self, p: Params, x: jax.Array, positions: jax.Array):
        """Projection + qk-norm + direct RoPE for a [B, S, d] sequence —
        shared by full-sequence attention and chunked slot prefill (keys
        leave here post-RoPE, paper §IV-C)."""
        cfg = self.cfg
        q, k, v = self._qkv(p, x)
        if cfg.rotary_dim:
            rot = functools.partial(rope_lib.apply_rope, base=cfg.rope_base,
                                    rotary_dim=cfg.rotary_dim)
            q = jnp.swapaxes(rot(jnp.swapaxes(q, 1, 2), positions), 1, 2)
            k = jnp.swapaxes(rot(jnp.swapaxes(k, 1, 2), positions), 1, 2)
        return q, k, v

    def _ffn_out(self, bp: Params, x: jax.Array) -> tuple[jax.Array, jax.Array]:
        """ln2 + (MoE | MLP) block tail, shared by the training block, the
        prefill step, and chunked slot prefill. Returns (y, moe aux)."""
        cfg = self.cfg
        h2 = rms_norm(x, bp["ln2"], cfg.norm_eps)
        if cfg.n_experts:
            return moe_lib.moe_apply(bp["ffn"], h2, top_k=cfg.top_k,
                                     act=cfg.act, gated=cfg.gated_mlp,
                                     capacity_factor=cfg.capacity_factor)
        return (mlp_apply(bp["ffn"], h2, cfg.act, cfg.gated_mlp),
                jnp.zeros((), jnp.float32))

    def _self_attn_full(self, p: Params, x: jax.Array,
                        positions: jax.Array,
                        kv_length: jax.Array | None = None) -> jax.Array:
        """Full-sequence self attention (training / encoder). ``kv_length``:
        optional [B] valid prefix — a bidirectional encoder run over padded
        inputs masks keys past each row's true length so valid positions'
        outputs are independent of the padding (queries at padded positions
        produce garbage that downstream reads mask out)."""
        cfg = self.cfg
        b, s, _ = x.shape
        q, k, v = self._qkv_rope(p, x, positions)
        out = attn_lib.prefill_attention(q, k, v, causal=self.causal,
                                         window=cfg.window,
                                         kv_lengths=kv_length,
                                         kv_block=cfg.attn_block or 512)
        return linear(p, "wo", out.reshape(b, s, -1))

    def _cross_attn_full(self, p: Params, x: jax.Array,
                         source: jax.Array) -> jax.Array:
        """Cross attention to a stub-frontend source sequence (no RoPE)."""
        b, s, _ = x.shape
        cfg = self.cfg
        dh = cfg.resolved_head_dim
        q, _, _ = self._qkv(p, x)
        k = linear(p, "wk", source).reshape(
            b, source.shape[1], cfg.n_kv_heads, dh)
        v = linear(p, "wv", source).reshape(
            b, source.shape[1], cfg.n_kv_heads, dh)
        if cfg.qk_norm:
            k = rms_norm(k, p["kn"], cfg.norm_eps)
        out = attn_lib.prefill_attention(q, k, v, causal=False,
                                         kv_block=cfg.attn_block or 512)
        out = linear(p, "wo", out.reshape(b, s, -1))
        return jnp.tanh(p["gate"]).astype(x.dtype) * out

    @staticmethod
    def _seq_shard(x: jax.Array):
        """Megatron-style sequence-sharded residual stream (train path):
        constrain [B, S, d] activations to (batch over DP, S over model)
        between blocks. GSPMD then reduce-scatters the row-parallel partial
        sums in bf16 *before* the f32 norm region and all-gathers before the
        next matmul — replacing f32 activation all-reduces with bf16 RS+AG
        (half the ICI bytes) and sharding the norm compute (§Perf)."""
        from repro.distributed.context import get_context
        ctx = get_context()
        if not ctx.active or x.ndim != 3 or x.shape[1] == 1:
            return x
        bd = ctx.batch_axes if x.shape[0] % ctx.axis_size(ctx.batch_axes) == 0 \
            else None
        s_ax = ctx.model_axis if x.shape[1] % ctx.axis_size(ctx.model_axis) == 0 \
            else None
        try:
            from jax.sharding import PartitionSpec as P
            return jax.lax.with_sharding_constraint(x, P(bd, s_ax, None))
        except Exception:
            return x

    # ---- full-sequence blocks (training / prefill math) --------------------
    def _self_block(self, p: Params, x: jax.Array, positions: jax.Array,
                    source: jax.Array | None,
                    kv_length: jax.Array | None = None
                    ) -> tuple[jax.Array, jax.Array]:
        cfg = self.cfg
        h = rms_norm(x, p["ln1"], cfg.norm_eps)
        attn_out = self._self_attn_full(p["attn"], h, positions, kv_length)
        if cfg.family == "hybrid":
            mamba_out = mamba_lib.mamba_forward(p["mamba"], h)
            mixed = 0.5 * (rms_norm(attn_out, p["ln_attn_out"], cfg.norm_eps)
                           + rms_norm(mamba_out, p["ln_mamba_out"], cfg.norm_eps))
            x = x + mixed
        else:
            x = x + attn_out
        if "cross" in p and source is not None:   # whisper-style in-layer cross
            x = x + self._cross_attn_full(
                p["cross"], rms_norm(x, p["ln_cross"], cfg.norm_eps), source)
        y, aux = self._ffn_out(p, x)
        return self._seq_shard(x + y), aux

    def _cross_block(self, p: Params, x: jax.Array,
                     source: jax.Array | None) -> jax.Array:
        cfg = self.cfg
        if source is not None:
            h = rms_norm(x, p["ln1"], cfg.norm_eps)
            x = x + self._cross_attn_full(p["cross"], h, source)
        h2 = rms_norm(x, p["ln2"], cfg.norm_eps)
        return self._seq_shard(x + mlp_apply(p["ffn"], h2, cfg.act,
                                             cfg.gated_mlp))

    # ---- forward (training) -----------------------------------------------
    def forward(self, params: Params, tokens: jax.Array | None = None, *,
                embeds: jax.Array | None = None,
                source: jax.Array | None = None,
                kv_length: jax.Array | None = None,
                remat: bool = True) -> tuple[jax.Array, jax.Array]:
        """Returns (hidden-or-logits [B,S,*], moe aux loss). ``tokens`` XOR
        ``embeds``; ``source``: [B, S_src, d] stub-frontend features;
        ``kv_length``: [B] valid input prefix for masked (padded) encoder
        runs — see :meth:`_self_attn_full`."""
        cfg = self.cfg
        x = (params["embed"].astype(self._dt)[tokens] if embeds is None
             else embeds.astype(self._dt))
        b, s, _ = x.shape
        positions = jnp.arange(s)

        if cfg.family == "ssm":
            x, aux = self._rwkv_forward(params, x, remat=remat)
        else:
            n_cross = self._n_cross_groups()
            group = cfg.cross_attn_every if n_cross else 0

            def self_step(carry, bp):
                x, aux = carry
                x, a = self._self_block(bp, x, positions, source, kv_length)
                return (x, aux + a), None

            step = make_remat(cfg)(self_step) if remat else self_step

            if not n_cross:
                (x, aux), _ = layer_scan(step, (x, 0.0), params["blocks"], unroll=cfg.unroll_layers)
            else:
                n_self_per = group - 1

                def group_step(carry, gp):
                    sp, cp = gp
                    (x, aux), _ = layer_scan(step, carry, sp, unroll=cfg.unroll_layers)
                    x = self._cross_block(cp, x, source)
                    return (x, aux), None

                gstep = make_remat(cfg)(group_step) if remat else group_step
                # reshape self blocks [n_self] -> [n_cross, n_self_per]
                sp = jax.tree.map(
                    lambda a: a.reshape(n_cross, n_self_per, *a.shape[1:]),
                    params["blocks"])
                (x, aux), _ = layer_scan(gstep, (x, 0.0),
                                         (sp, params["cross_blocks"]),
                                         unroll=cfg.unroll_layers)

        x = rms_norm(x, params["ln_f"], cfg.norm_eps)
        return self._unembed(params, x), aux

    def _rwkv_forward(self, params, x, remat: bool = True):
        cfg = self.cfg
        b = x.shape[0]

        def step(carry, bp):
            x = carry
            st = rwkv_lib.RWKVLayerState(
                x_prev_att=jnp.zeros((b, cfg.d_model), x.dtype),
                x_prev_ffn=jnp.zeros((b, cfg.d_model), x.dtype),
                wkv=jnp.zeros((b, cfg.d_model // cfg.rwkv_head_dim,
                               cfg.rwkv_head_dim, cfg.rwkv_head_dim), jnp.float32))
            h = rms_norm(x, bp["ln1"], cfg.norm_eps)
            y, st = rwkv_lib.rwkv_time_mix(bp["mix"], h, st, cfg.rwkv_head_dim)
            x = x + y
            h2 = rms_norm(x, bp["ln2"], cfg.norm_eps)
            y2, _ = rwkv_lib.rwkv_channel_mix(bp["mix"], h2, st)
            return x + y2, None

        step_fn = make_remat(cfg)(step) if remat else step
        x, _ = layer_scan(step_fn, x, params["blocks"],
                          unroll=cfg.unroll_layers)
        return x, jnp.zeros((), jnp.float32)

    @property
    def _dt(self):
        return jnp.dtype(self.cfg.compute_dtype)

    def _unembed(self, params: Params, x: jax.Array) -> jax.Array:
        if not self.with_embedding:
            return x
        w = (params["embed"].T if self.cfg.tie_embeddings
             else params["unembed"])
        logits = (x @ w.astype(x.dtype)).astype(jnp.float32)
        # pin (batch over DP, vocab over model): see layers.batch_vocab_constrain
        return batch_vocab_constrain(logits)

    # =======================================================================
    # Serving: KV cache init / prefill / decode_step
    # =======================================================================
    def init_cache(self, batch: int, max_len: int,
                   source_len: int | None = None, *,
                   n_sources: int | None = None,
                   chunk: int | None = None,
                   kv_dtype=None) -> Cache:
        """Preallocated decode state. KV tensors [L, B, Smax, Hkv, Dh] in the
        KV storage dtype; per-row lengths; incremental-RoPE angle state
        (Eq. 11); family-specific recurrent states.

        ``kv_dtype``: storage dtype for the self-attention KV cache.
        Defaults to ``int8`` for ``+w4a8`` configs (``cfg.w4a8_serve``),
        else the compute dtype — the old behavior *assumed* compute dtype
        everywhere, which is exactly the latent coupling this parameter
        removes. An int8 cache additionally allocates per-(layer, slot,
        head, position) **bf16** dequant scales ``k_scale/v_scale
        [L, B, Hkv, Smax]`` (position last: it is the blocked axis every
        consumer tiles over) plus pooled-source twins
        ``src_k_scale/src_v_scale [Lc, E, Hkv, S_src]`` when a source-KV
        pool exists. Scales are computed in f32 and stored bf16 — the
        per-Dh-element overhead halves to 2 bytes, so the int8 footprint
        is ``0.25 + 0.5/Dh`` of fp32 (vs ``0.25 + 1/Dh`` with f32
        scales, which overshoots the 0.3x budget at small head dims);
        consumers dequantize in f32, promotion covers the mixed multiply. Per-row lock-step ``cross_k/cross_v`` stay in the
        compute dtype: they are written once per ``prefill`` batch and
        carry no per-slot lifecycle, so quantizing them buys nothing the
        pool form doesn't already cover.

        Cross-attention source KV comes in two forms. ``source_len`` alone
        (lock-step serving) allocates per-row ``cross_k/cross_v``
        ``[Lc, B, S_src, Hkv, Dh]`` + per-row ``source_len``, filled by
        ``prefill``. ``n_sources`` (continuous serving) instead allocates a
        **pooled** form: ``src_k/src_v [Lc, n_sources, S_src, Hkv, Dh]``
        entries shared across slots, ``src_len [n_sources]`` valid prefixes,
        and ``src_index [B]`` mapping each slot to its entry — written once
        per source by :meth:`ingest_source`, read-only at decode
        (``repro.serving.slot_pool.SourceKVPool`` owns the host ledger).

        ``chunk``: the serving engine's prefill chunk size. Ring caches use
        it to size the ring as ``round128(window + chunk)`` so the chunked-
        prefill exactness bound ``ring_len >= window + chunk - 1`` holds by
        construction — a window just under a 128 boundary no longer forces
        the engine to reject large chunks (the ring simply takes the next
        128 step, degenerating to the full cache when that reaches
        ``max_len``)."""
        cfg = self.cfg
        dh = cfg.resolved_head_dim
        dt = self._dt
        cache: Cache = {"len": jnp.zeros((batch,), jnp.int32)}
        if cfg.family == "ssm":
            h = cfg.d_model // cfg.rwkv_head_dim
            cache.update(
                rwkv_att=jnp.zeros((cfg.n_layers, batch, cfg.d_model), dt),
                rwkv_ffn=jnp.zeros((cfg.n_layers, batch, cfg.d_model), dt),
                rwkv_wkv=jnp.zeros((cfg.n_layers, batch, h, cfg.rwkv_head_dim,
                                    cfg.rwkv_head_dim), jnp.float32))
            return cache
        n_cross = self._n_cross_groups()
        n_self = cfg.n_layers - n_cross
        kv_len = max_len
        if cfg.kv_ring and cfg.window:
            # ring cache: ~window slots regardless of context (SWA archs).
            # Decode needs ring_len >= window + 1 (the new token's write
            # must only ever evict the position leaving the window); chunked
            # serving additionally needs ring_len >= window + chunk - 1 for
            # prefill exactness under wraparound, so when the caller passes
            # its chunk the ring is sized round128(window + chunk) and the
            # bound holds by construction. The 128-rounding keeps the
            # sublane dimension aligned either way.
            want = cfg.window + (chunk if chunk else 1)
            kv_len = min(max_len, -(-want // 128) * 128)
        if cfg.decode_impl == "kernel":
            # kernel-path alignment contract (kernels/swiftkv_decode/ops.py):
            # the cache streams zero-copy through BlockSpec index maps, so
            # max_len must be block-divisible at init — a 128 multiple always
            # admits a power-of-two block, and a small cache (<= 128, one
            # block) needs only sublane alignment (multiple of 8); a
            # misaligned cache would raise at the first decode step instead
            # of silently paying a per-step whole-cache pad+copy
            mult = 128 if kv_len > 128 else 8
            kv_len = -(-kv_len // mult) * mult
        kv_dt = (jnp.dtype(kv_dtype) if kv_dtype is not None
                 else (jnp.dtype(jnp.int8) if cfg.w4a8_serve else dt))
        cache["k"] = jnp.zeros((n_self, batch, kv_len, cfg.n_kv_heads, dh),
                               kv_dt)
        cache["v"] = jnp.zeros_like(cache["k"])
        if kv_dt == jnp.int8:
            cache["k_scale"] = jnp.zeros(
                (n_self, batch, cfg.n_kv_heads, kv_len), jnp.bfloat16)
            cache["v_scale"] = jnp.zeros_like(cache["k_scale"])
        if cfg.rotary_dim:
            rs = rope_lib.rope_state_init(dh, cfg.rope_base, 0, cfg.rotary_dim)
            cache["rope_cos"] = jnp.broadcast_to(rs.cos_m, (batch, rs.cos_m.shape[0]))
            cache["rope_sin"] = jnp.broadcast_to(rs.sin_m, (batch, rs.sin_m.shape[0]))
        if cfg.family == "hybrid":
            d_inner = cfg.ssm_expand * cfg.d_model
            cache["mamba_conv"] = jnp.zeros(
                (n_self, batch, cfg.ssm_conv - 1, d_inner), jnp.float32)
            cache["mamba_ssm"] = jnp.zeros(
                (n_self, batch, d_inner, cfg.ssm_state), jnp.float32)
        n_cross_kv = (n_cross if cfg.cross_attn_every > 1
                      else (cfg.n_layers if cfg.cross_attn_every == 1 else 0))
        if n_cross_kv and source_len and n_sources:
            # pooled source KV (continuous serving): entries keyed by source
            # id on the host side, shared read-only across slots
            cache["src_k"] = jnp.zeros(
                (n_cross_kv, n_sources, source_len, cfg.n_kv_heads, dh),
                kv_dt)
            cache["src_v"] = jnp.zeros_like(cache["src_k"])
            cache["src_len"] = jnp.zeros((n_sources,), jnp.int32)
            cache["src_index"] = jnp.zeros((batch,), jnp.int32)
            if kv_dt == jnp.int8:
                cache["src_k_scale"] = jnp.zeros(
                    (n_cross_kv, n_sources, cfg.n_kv_heads, source_len),
                    jnp.bfloat16)
                cache["src_v_scale"] = jnp.zeros_like(cache["src_k_scale"])
        elif n_cross_kv and source_len:
            cache["cross_k"] = jnp.zeros(
                (n_cross_kv, batch, source_len, cfg.n_kv_heads, dh), dt)
            cache["cross_v"] = jnp.zeros_like(cache["cross_k"])
            cache["source_len"] = jnp.full((batch,), source_len, jnp.int32)
        return cache

    def _rope_qk_decode(self, cache: Cache, q: jax.Array, k: jax.Array,
                        lengths: jax.Array):
        """Rotate the new token's q/k at its absolute position. ``incremental``
        uses the cached Eq. 11 angle state; ``direct`` recomputes cos/sin."""
        cfg = self.cfg
        if not cfg.rotary_dim:
            return q, k
        if cfg.rope_mode == "incremental":
            cos, sin = cache["rope_cos"], cache["rope_sin"]      # [B, rd/2]
            rd = 2 * cos.shape[-1]
            def rot(x):                                          # x: [B, H, Dh]
                xr, xp = x[..., :rd], x[..., rd:]
                x1, x2 = xr[..., : rd // 2], xr[..., rd // 2:]
                c, s = cos[:, None, :].astype(x.dtype), sin[:, None, :].astype(x.dtype)
                return jnp.concatenate([x1 * c - x2 * s, x1 * s + x2 * c, xp], -1)
            return rot(q), rot(k)
        rot = lambda x: jax.vmap(
            lambda xx, m: rope_lib.apply_rope(xx, m[None], cfg.rope_base,
                                              cfg.rotary_dim))(
            x[:, :, None, :], lengths)[:, :, 0, :]
        return rot(q), rot(k)

    def _advance_rope(self, cache: Cache) -> Cache:
        cfg = self.cfg
        if cfg.rotary_dim and cfg.rope_mode == "incremental":
            rs = rope_lib.RopeState(
                cos_m=cache["rope_cos"], sin_m=cache["rope_sin"],
                a=jnp.cos(rope_lib.rope_freqs(self.cfg.resolved_head_dim,
                                              cfg.rope_base, cfg.rotary_dim)),
                b=jnp.sin(rope_lib.rope_freqs(self.cfg.resolved_head_dim,
                                              cfg.rope_base, cfg.rotary_dim)))
            rs = rope_lib.rope_state_advance(rs)
            cache = dict(cache, rope_cos=rs.cos_m, rope_sin=rs.sin_m)
        return cache

    @staticmethod
    def _write_kv(kc: jax.Array, vc: jax.Array, k: jax.Array, v: jax.Array,
                  lengths: jax.Array, active: jax.Array | None = None):
        """kc/vc: [B, Smax, Hkv, Dh]; k/v: [B, Hkv, Dh] written at per-row
        position ``lengths`` (mod ring size — a full-context cache never
        wraps; a ring cache overwrites the slot that just left the window).

        ``active``: optional [B] bool **per-slot write mask** — rows with
        ``active=False`` rewrite their old value (an in-place no-op). This
        is the ragged-decode parking mechanism for ring caches: a ring has
        no dead tail row to park on (every slot is, or will wrap into, a
        live window position), so a parked write must not move data at
        all. Full caches park on the reserved tail row instead and pass
        ``active=None``."""
        r = kc.shape[1]
        if active is None:
            def upd(c, x, l):
                return jax.lax.dynamic_update_slice(c, x[None], (l % r, 0, 0))
            kc = jax.vmap(upd)(kc, k, lengths)
            vc = jax.vmap(upd)(vc, v, lengths)
            return kc, vc

        def upd_masked(c, x, l, a):
            old = jax.lax.dynamic_slice(c, (l % r, 0, 0), (1, *c.shape[1:]))
            return jax.lax.dynamic_update_slice(
                c, jnp.where(a, x[None], old), (l % r, 0, 0))
        kc = jax.vmap(upd_masked)(kc, k, lengths, active)
        vc = jax.vmap(upd_masked)(vc, v, lengths, active)
        return kc, vc

    @staticmethod
    def _write_kv_scales(ksc: jax.Array, vsc: jax.Array, ks: jax.Array,
                         vs: jax.Array, lengths: jax.Array,
                         active: jax.Array | None = None):
        """Scale twin of :meth:`_write_kv` for the int8 cache: ksc/vsc
        [B, Hkv, Smax] bf16 scale planes; ks/vs [B, Hkv] per-head scales of
        the new token, written at ``lengths % Smax`` on the position axis
        with the **same** parking semantics (``active=None`` writes
        unconditionally — full caches park on the reserved tail row whose
        write target already encodes the parking; ``active`` is the ring
        per-slot rewrite-in-place mask)."""
        ks = ks.astype(ksc.dtype)
        vs = vs.astype(vsc.dtype)
        r = ksc.shape[-1]
        if active is None:
            def upd(c, x, l):
                return jax.lax.dynamic_update_slice(c, x[:, None], (0, l % r))
            return jax.vmap(upd)(ksc, ks, lengths), \
                jax.vmap(upd)(vsc, vs, lengths)

        def upd_masked(c, x, l, a):
            old = jax.lax.dynamic_slice(c, (0, l % r), (c.shape[0], 1))
            return jax.lax.dynamic_update_slice(
                c, jnp.where(a, x[:, None], old), (0, l % r))
        return jax.vmap(upd_masked)(ksc, ks, lengths, active), \
            jax.vmap(upd_masked)(vsc, vs, lengths, active)

    def _decode_self_attn(self, p: Params, h: jax.Array, kc, vc,
                          cache: Cache, active: jax.Array | None = None,
                          ksc=None, vsc=None):
        cfg = self.cfg
        b, d = h.shape
        dh = cfg.resolved_head_dim
        q = linear(p, "wq", h).reshape(b, cfg.n_heads, dh)
        k = linear(p, "wk", h).reshape(b, cfg.n_kv_heads, dh)
        v = linear(p, "wv", h).reshape(b, cfg.n_kv_heads, dh)
        if cfg.qk_norm:
            q = rms_norm(q, p["qn"], cfg.norm_eps)
            k = rms_norm(k, p["kn"], cfg.norm_eps)
        q, k = self._rope_qk_decode(cache, q, k, cache["len"])
        ring = bool(cfg.kv_ring and cfg.window)
        write_mask = None
        if active is None:
            write_at, attn_len = cache["len"], cache["len"] + 1
        elif ring:
            # ragged ring batch: inactive rows have no dead row to park on
            # (the tail is a live window slot once wrapped), so parking is a
            # per-slot write *mask* — the row rewrites its old value in
            # place — plus a 1-token stub attention length
            write_at = cache["len"]
            attn_len = jnp.where(active, cache["len"] + 1, 1)
            write_mask = active
        else:
            # ragged batch: inactive rows (free / mid-prefill slots) park
            # their discarded KV write on the reserved tail row and attend a
            # 1-token stub — the batch keeps its static shape while slot
            # membership changes (serving/slot_pool.py reserves the tail)
            write_at = jnp.where(active, cache["len"], kc.shape[1] - 1)
            attn_len = jnp.where(active, cache["len"] + 1, 1)
        if ksc is not None:
            # int8 cache: quantize the new token's K/V over Dh per head —
            # the write parks/wraps exactly like the fp path, and the scale
            # plane parks with it so released rows stay (0, scale 0)
            k, k_s = quantize_kv(k)
            v, v_s = quantize_kv(v)
            ksc, vsc = self._write_kv_scales(ksc, vsc, k_s, v_s,
                                             write_at, write_mask)
        kc, vc = self._write_kv(kc, vc, k.astype(kc.dtype), v.astype(vc.dtype),
                                write_at, write_mask)
        out = attn_lib.decode_attention(q, kc, vc, attn_len,
                                        impl=cfg.decode_impl,
                                        window=cfg.window, ring=ring,
                                        block_size=cfg.attn_block or 512,
                                        k_scale=ksc, v_scale=vsc)
        return linear(p, "wo", out.reshape(b, -1)), kc, vc, ksc, vsc

    def _decode_cross_attn(self, p: Params, h: jax.Array, ck, cv,
                           source_len: jax.Array) -> jax.Array:
        """Per-row (lock-step) cross read: ck/cv are [B, S_src, Hkv, Dh]
        caches written by :meth:`prefill`; ``source_len`` [B] masks each
        row's padded source tail."""
        cfg = self.cfg
        b, d = h.shape
        dh = cfg.resolved_head_dim
        q = linear(p, "wq", h).reshape(b, cfg.n_heads, dh)
        if cfg.qk_norm:
            q = rms_norm(q, p["qn"], cfg.norm_eps)
        impl = "blockwise" if cfg.decode_impl == "sp" else cfg.decode_impl
        out = attn_lib.decode_attention(q, ck, cv, source_len,
                                        impl=impl,
                                        block_size=cfg.attn_block or 512)
        out = linear(p, "wo", out.reshape(b, -1))
        return jnp.tanh(p["gate"]).astype(h.dtype) * out

    def _decode_cross_attn_pooled(self, p: Params, h: jax.Array, sk, sv,
                                  entries: jax.Array, src_len: jax.Array,
                                  sk_sc=None, sv_sc=None) -> jax.Array:
        """Pooled (continuous-serving) cross read: sk/sv are one layer's
        slice of the source-KV pool, ``[n_entries, S_src, Hkv, Dh]`` —
        shared across slots, NOT batched — and ``entries``/``src_len`` map
        each slot to its entry and that entry's valid source prefix. The
        blockwise read streams each row's entry straight out of the pool
        (``swiftkv_decode_pooled``); a ``src_len == 0`` row (no source, or
        a freed slot pointing at a zeroed entry) reads an exact zero, so
        the gated output vanishes for it."""
        cfg = self.cfg
        b, d = h.shape
        dh = cfg.resolved_head_dim
        q = linear(p, "wq", h).reshape(b, cfg.n_heads, dh)
        if cfg.qk_norm:
            q = rms_norm(q, p["qn"], cfg.norm_eps)
        impl = ("naive" if cfg.decode_impl == "naive" else "blockwise")
        out = attn_lib.decode_cross_attention(
            q, sk, sv, entries, jnp.take(src_len, entries), impl=impl,
            block_size=cfg.attn_block or 512, k_scale=sk_sc, v_scale=sv_sc)
        out = linear(p, "wo", out.reshape(b, -1))
        return jnp.tanh(p["gate"]).astype(h.dtype) * out

    def _decode_block(self, bp: Params, slices: dict, x: jax.Array,
                      cache: Cache, active: jax.Array | None = None
                      ) -> tuple[jax.Array, dict]:
        """One self block at decode time. ``slices`` holds this layer's cache
        tensors; returns updated slices as scan ys."""
        cfg = self.cfg
        new = {}
        h = rms_norm(x, bp["ln1"], cfg.norm_eps)
        attn_out, new["k"], new["v"], ksc, vsc = self._decode_self_attn(
            bp["attn"], h, slices["k"], slices["v"], cache, active,
            slices.get("k_scale"), slices.get("v_scale"))
        if ksc is not None:
            new["k_scale"], new["v_scale"] = ksc, vsc
        if cfg.family == "hybrid":
            st = mamba_lib.MambaState(conv=slices["mamba_conv"],
                                      ssm=slices["mamba_ssm"])
            # ragged batch: inactive rows carry their recurrent state through
            # unchanged — masked at the state-update site in mamba.py
            m_out, st = mamba_lib.mamba_decode_step(bp["mamba"], h, st,
                                                    active=active)
            new["mamba_conv"], new["mamba_ssm"] = st.conv, st.ssm
            x = x + 0.5 * (rms_norm(attn_out, bp["ln_attn_out"], cfg.norm_eps)
                           + rms_norm(m_out, bp["ln_mamba_out"], cfg.norm_eps))
        else:
            x = x + attn_out
        if "cross" in bp and "src_k" in slices:
            # pooled source KV (continuous serving): read-only, per-slot
            # entry indirection via cache["src_index"]
            hc = rms_norm(x, bp["ln_cross"], cfg.norm_eps)
            x = x + self._decode_cross_attn_pooled(
                bp["cross"], hc, slices["src_k"], slices["src_v"],
                cache["src_index"], cache["src_len"],
                slices.get("src_k_scale"), slices.get("src_v_scale"))
        elif "cross" in bp and "cross_k" in slices:
            hc = rms_norm(x, bp["ln_cross"], cfg.norm_eps)
            x = x + self._decode_cross_attn(bp["cross"], hc, slices["cross_k"],
                                            slices["cross_v"],
                                            cache["source_len"])
            new["cross_k"], new["cross_v"] = slices["cross_k"], slices["cross_v"]
        h2 = rms_norm(x, bp["ln2"], cfg.norm_eps)
        if cfg.n_experts:
            # capacity-free per-row dispatch at decode: identical math to the
            # capacity path when nothing drops, but a row's output depends
            # only on that row — batch composition can't perturb a request
            # (ragged serving's per-request-equivalence contract), and at
            # B = n_slots it is also the cheaper form
            y, _ = moe_lib.moe_apply_rowwise(bp["ffn"], h2, top_k=cfg.top_k,
                                             act=cfg.act, gated=cfg.gated_mlp)
        else:
            y = mlp_apply(bp["ffn"], h2, cfg.act, cfg.gated_mlp)
        return x + y, new

    def decode_step(self, params: Params, tokens: jax.Array,
                    cache: Cache, active: jax.Array | None = None
                    ) -> tuple[jax.Array, Cache]:
        """tokens: [B] int32 -> (logits [B, V] f32, updated cache).

        ``active``: optional [B] bool — the ragged continuous-batching form.
        Active rows decode normally; inactive rows (free or mid-prefill
        slots) ride through with a parked KV write, a stub attention length,
        and *no* ``len`` advance, so the jit'd step keeps a static [B] shape
        while slot membership changes between steps. Recurrent-state
        families (ssm / hybrid) have no parking row — the row *is* the
        state — so inactive rows carry their (wkv / conv, ssm) state through
        unchanged via ``jnp.where`` selects. Ring KV caches (``kv_ring``
        SWA configs) have no parking row either — every ring slot is, or
        wraps into, a live window position — so their inactive rows park
        via a per-slot write *mask* (:meth:`_write_kv` ``active=``), the
        row rewriting its old value in place. Cross-attention source KV
        needs no parking at all: the pooled ``src_k/src_v`` entries are
        read-only at decode (each row reads its ``src_index`` entry masked
        to that entry's ``src_len``; an inactive row's read is discarded),
        so nothing an inactive row does can corrupt shared source state.
        The per-row incremental-RoPE
        state still advances for every row; a slot's state is reseeded by
        ``finalize_slot`` when a new request fills it."""
        cfg = self.cfg
        x = params["embed"].astype(self._dt)[tokens]             # [B, d]

        if cfg.family == "ssm":
            return self._rwkv_decode_step(params, x, cache, active)

        n_cross = self._n_cross_groups()

        def step(x, xs):
            bp, slices = xs
            x, new = self._decode_block(bp, slices, x, cache, active)
            return x, new

        self_slices = {"k": cache["k"], "v": cache["v"]}
        if "k_scale" in cache:
            self_slices["k_scale"] = cache["k_scale"]
            self_slices["v_scale"] = cache["v_scale"]
        if cfg.family == "hybrid":
            self_slices["mamba_conv"] = cache["mamba_conv"]
            self_slices["mamba_ssm"] = cache["mamba_ssm"]
        if cfg.cross_attn_every == 1:                  # whisper-style
            if "src_k" in cache:                       # pooled source KV
                self_slices["src_k"] = cache["src_k"]
                self_slices["src_v"] = cache["src_v"]
                if "src_k_scale" in cache:
                    self_slices["src_k_scale"] = cache["src_k_scale"]
                    self_slices["src_v_scale"] = cache["src_v_scale"]
            elif "cross_k" in cache:                   # per-row (lock-step)
                self_slices["cross_k"] = cache["cross_k"]
                self_slices["cross_v"] = cache["cross_v"]
            # neither: no source was ever provided — cross-attn contributes
            # nothing (matches prefill/forward with source=None)

        if not n_cross:
            x, new = layer_scan(step, x, (params["blocks"], self_slices), unroll=cfg.unroll_layers)
        else:
            group = cfg.cross_attn_every
            n_self_per = group - 1
            if "src_k" in cache:
                cross_xs, cross_mode = (cache["src_k"], cache["src_v"]), "pooled"
                if "src_k_scale" in cache:
                    cross_xs += (cache["src_k_scale"], cache["src_v_scale"])
            elif "cross_k" in cache:
                cross_xs, cross_mode = (cache["cross_k"], cache["cross_v"]), "perrow"
            else:
                # sourceless decode: the dedicated cross layer still applies
                # its FFN; only the (gated) cross-attention term vanishes
                cross_xs, cross_mode = (), "none"

            def group_step(x, xs):
                gp, gs, cp, *ckv = xs
                x, new = layer_scan(step, x, (gp, gs), unroll=cfg.unroll_layers)
                h = rms_norm(x, cp["ln1"], cfg.norm_eps)
                if cross_mode == "pooled":
                    x = x + self._decode_cross_attn_pooled(
                        cp["cross"], h, ckv[0], ckv[1],
                        cache["src_index"], cache["src_len"],
                        *(ckv[2:4] if len(ckv) > 2 else (None, None)))
                elif cross_mode == "perrow":
                    x = x + self._decode_cross_attn(cp["cross"], h, ckv[0],
                                                    ckv[1],
                                                    cache["source_len"])
                h2 = rms_norm(x, cp["ln2"], cfg.norm_eps)
                x = x + mlp_apply(cp["ffn"], h2, cfg.act, cfg.gated_mlp)
                return x, new

            gp = jax.tree.map(
                lambda a: a.reshape(n_cross, n_self_per, *a.shape[1:]),
                params["blocks"])
            gs = jax.tree.map(
                lambda a: a.reshape(n_cross, n_self_per, *a.shape[1:]),
                self_slices)
            x, new = layer_scan(group_step, x,
                                (gp, gs, params["cross_blocks"], *cross_xs),
                                unroll=cfg.unroll_layers)
            new = jax.tree.map(
                lambda a: a.reshape(n_cross * n_self_per, *a.shape[2:]), new)

        cache = dict(cache)
        for key in ("k", "v", "k_scale", "v_scale",
                    "mamba_conv", "mamba_ssm"):
            if key in new:
                cache[key] = new[key]
        cache["len"] = cache["len"] + (1 if active is None
                                       else active.astype(jnp.int32))
        cache = self._advance_rope(cache)
        x = rms_norm(x, params["ln_f"], cfg.norm_eps)
        return self._unembed(params, x), cache

    # ---- multi-tick decode: K fused ticks, one dispatch --------------------
    def decode_multi(self, params: Params, tok: jax.Array, cache: Cache,
                     active: jax.Array, budget: jax.Array,
                     serials: jax.Array, emitted: jax.Array, n_ticks: int,
                     *, eos_id: int | None = None, temperature: float = 0.0,
                     rng_key: jax.Array | None = None,
                     poison: jax.Array | None = None
                     ) -> tuple[jax.Array, jax.Array, jax.Array, Cache]:
        """Fuse ``n_ticks`` ragged decode ticks into one program: a
        ``lax.scan`` over the :meth:`decode_step` body with per-tick
        Gumbel-max sampling and **on-device retirement**, so the host syncs
        once per K tokens instead of once per token.

        Control state is device-resident for the whole block: per tick, an
        active row decodes, samples its next token (greedy argmax when
        ``temperature == 0``, else Gumbel-max keyed on
        ``(rng_key, serial, token index)`` — request-intrinsic, so draws are
        tick-horizon-independent by construction), advances its ``emitted``
        counter, and *retires itself mid-scan* when the sampled token hits
        ``eos_id`` or the counter reaches its ``budget`` — the row's
        ``active`` bit flips and from the next tick it parks its KV writes /
        carries its recurrent state exactly like any other inactive row.
        Works unchanged for every ragged family because the scanned body IS
        ``decode_step(active=...)``: MHA/GQA/SWA park KV on the reserved
        tail row, ssm/hybrid rows mask their state carries
        (rwkv6.rwkv_*_step / mamba.mamba_decode_step ``active=``), and MoE
        rows use the capacity-free per-row dispatch, so a row's tokens
        cannot depend on when its neighbours retire inside the block.

        tok/serials/emitted: [B] int32; active: [B] bool; budget: [B] int32
        (per-slot total token allowance, i.e. ``max_new_tokens``).
        Returns ``(tok_block [K, B] int32, active [B], emitted [B], cache)``
        where ``tok_block[t, b]`` is the token row ``b`` emitted at tick
        ``t``, or ``-1`` if the row was inactive — the host replays
        retirement from the block alone, no per-tick sync.

        **On-device health check**: every tick verifies each active row's
        logits are finite before trusting the sampled token. A row whose
        logits contain NaN/inf emits the sentinel ``-2`` in ``tok_block``
        and self-retires (its ``active`` bit flips, ``emitted`` does not
        advance, its KV writes park from the next tick) — the quarantine
        signal rides the existing ``[K, B]`` sync at zero extra transfers,
        and with all-finite logits every output is bit-identical to the
        uncheck'd program. ``poison``: optional [B] bool fault-injection
        mask (see :mod:`repro.serving.faults`) that overwrites masked rows'
        logits with NaN each tick, exercising exactly that detection path;
        ``None`` (the default) compiles no poisoning code."""
        if rng_key is None:
            rng_key = jax.random.PRNGKey(0)

        def pick_tokens(logits, emitted):
            if temperature == 0.0:
                return jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return jax.vmap(
                lambda row, serial, idx: seeded_gumbel_pick(
                    rng_key, row, serial, idx, temperature)
            )(logits, serials, emitted)

        def tick(carry, _):
            tok, cache, active, emitted = carry
            logits, cache = self.decode_step(params, tok, cache, active)
            if poison is not None:
                logits = jnp.where(poison[:, None], jnp.nan, logits)
            finite = jnp.all(jnp.isfinite(logits), axis=-1)
            pick = pick_tokens(logits, emitted)
            ok = active & finite
            emitted = jnp.where(ok, emitted + 1, emitted)
            done = emitted >= budget
            if eos_id is not None:
                done |= pick == eos_id
            # healthy rows report their token; a non-finite row reports the
            # -2 quarantine sentinel; inactive rows stay -1
            out = jnp.where(active,
                            jnp.where(finite, pick, jnp.int32(-2)),
                            jnp.int32(-1))
            active = ok & ~done
            # a retired row's final token is emitted but never fed back —
            # exactly the single-tick engine's contract
            tok = jnp.where(active, pick, tok)
            return (tok, cache, active, emitted), out

        (tok, cache, active, emitted), tok_block = jax.lax.scan(
            tick, (tok, cache, active, emitted), None, length=n_ticks)
        return tok_block, active, emitted, cache

    # ---- prefill: full-prompt forward that also fills the cache ------------
    def prefill(self, params: Params, tokens: jax.Array, cache: Cache,
                source: jax.Array | None = None,
                source_len: jax.Array | None = None) -> tuple[jax.Array, Cache]:
        """tokens: [B, Sp] (uniform prompt length — serving drivers pad to
        length groups); returns (last-position logits [B, V] f32, filled
        cache). Keys are cached post-RoPE (paper §IV-C).

        ``source``: [B, S_src, d] frontend features for cross-attention
        stacks; ``source_len``: optional [B] valid source prefixes when
        rows carry sources of different true lengths padded to S_src —
        cross reads mask the padded tails here and ``cache['source_len']``
        records them so decode masks identically. ``source=None`` on a
        cross config means *no source*: the (gated) cross-attention term
        contributes nothing, while a dedicated (vlm-style) cross layer
        still applies its FFN."""
        cfg = self.cfg
        b, sp = tokens.shape
        x = params["embed"].astype(self._dt)[tokens]
        positions = jnp.arange(sp)

        if cfg.family == "ssm":
            return self._rwkv_prefill(params, x, cache)

        n_cross = self._n_cross_groups()
        dh = cfg.resolved_head_dim

        def kv_for(p, h, with_rope: bool):
            k = linear(p, "wk", h).reshape(b, -1, cfg.n_kv_heads, dh)
            v = linear(p, "wv", h).reshape(b, -1, cfg.n_kv_heads, dh)
            if cfg.qk_norm:
                k = rms_norm(k, p["kn"], cfg.norm_eps)
            if with_rope and cfg.rotary_dim:
                k = jnp.swapaxes(rope_lib.apply_rope(
                    jnp.swapaxes(k, 1, 2), positions, cfg.rope_base,
                    cfg.rotary_dim), 1, 2)
            return k, v

        def fill_kv(ck, kv):
            # full cache: contiguous write at 0; ring cache: write the last
            # R tokens at their (pos % R) slots
            r = ck.shape[2] if ck.ndim == 5 else ck.shape[1]
            if kv.shape[1] <= r:
                return jax.lax.dynamic_update_slice(
                    ck, kv.astype(ck.dtype), (0,) * ck.ndim)
            import numpy as _np
            m = r
            pos = _np.arange(kv.shape[1] - m, kv.shape[1])
            slots = pos % r
            order = _np.argsort(slots)
            return ck.at[:, slots[order]].set(
                kv[:, kv.shape[1] - m:][:, order].astype(ck.dtype))

        def fill_scale(csc, sc):
            # scale twin of fill_kv: csc [B, Hkv, R] (position last), sc
            # [B, Sp, Hkv] from quantize_kv — same contiguous-or-ring write
            sc = jnp.swapaxes(sc, 1, 2).astype(csc.dtype)  # [B, Hkv, Sp]
            r = csc.shape[-1]
            if sc.shape[-1] <= r:
                return jax.lax.dynamic_update_slice(csc, sc, (0, 0, 0))
            import numpy as _np
            pos = _np.arange(sc.shape[-1] - r, sc.shape[-1])
            slots = pos % r
            order = _np.argsort(slots)
            return csc.at[:, :, slots[order]].set(
                sc[:, :, sc.shape[-1] - r:][:, :, order])

        def self_step(x, xs):
            bp, slices = xs
            new = {}
            h = rms_norm(x, bp["ln1"], cfg.norm_eps)
            q = linear(bp["attn"], "wq", h).reshape(
                b, sp, cfg.n_heads, dh)
            if cfg.qk_norm:
                q = rms_norm(q, bp["attn"]["qn"], cfg.norm_eps)
            if cfg.rotary_dim:
                q = jnp.swapaxes(rope_lib.apply_rope(
                    jnp.swapaxes(q, 1, 2), positions, cfg.rope_base,
                    cfg.rotary_dim), 1, 2)
            k, v = kv_for(bp["attn"], h, with_rope=True)
            if "k_scale" in slices:
                # int8 cache: the cache write quantizes; attention below
                # still consumes the fresh fp K/V, so full-prefill logits
                # are untouched by the storage format
                kq, k_s = quantize_kv(k)
                vq, v_s = quantize_kv(v)
                new["k"] = fill_kv(slices["k"], kq)
                new["v"] = fill_kv(slices["v"], vq)
                new["k_scale"] = fill_scale(slices["k_scale"], k_s)
                new["v_scale"] = fill_scale(slices["v_scale"], v_s)
            else:
                new["k"] = fill_kv(slices["k"], k)
                new["v"] = fill_kv(slices["v"], v)
            attn = attn_lib.prefill_attention(q, k, v, causal=True,
                                              window=cfg.window,
                                              kv_block=cfg.attn_block or 512)
            attn_out = linear(bp["attn"], "wo", attn.reshape(b, sp, -1))
            if cfg.family == "hybrid":
                m_out, mst = mamba_lib.mamba_forward(bp["mamba"], h,
                                                     return_state=True)
                new["mamba_conv"], new["mamba_ssm"] = mst.conv, mst.ssm
                x = x + 0.5 * (rms_norm(attn_out, bp["ln_attn_out"], cfg.norm_eps)
                               + rms_norm(m_out, bp["ln_mamba_out"], cfg.norm_eps))
            else:
                x = x + attn_out
            if "cross" in bp and source is not None:
                hc = rms_norm(x, bp["ln_cross"], cfg.norm_eps)
                ck, cv = kv_for(bp["cross"], source.astype(h.dtype),
                                with_rope=False)
                new["cross_k"] = ck.astype(slices["cross_k"].dtype)
                new["cross_v"] = cv.astype(slices["cross_v"].dtype)
                qc = linear(bp["cross"], "wq", hc).reshape(
                    b, sp, cfg.n_heads, dh)
                if cfg.qk_norm:
                    qc = rms_norm(qc, bp["cross"]["qn"], cfg.norm_eps)
                c_out = attn_lib.prefill_attention(
                    qc, ck, cv, causal=False, kv_lengths=source_len,
                    kv_block=cfg.attn_block or 512)
                c_out = linear(bp["cross"], "wo", c_out.reshape(b, sp, -1))
                x = x + jnp.tanh(bp["cross"]["gate"]).astype(h.dtype) * c_out
            y, _ = self._ffn_out(bp, x)
            return x + y, new

        self_slices = {"k": cache["k"], "v": cache["v"]}
        if "k_scale" in cache:
            self_slices["k_scale"] = cache["k_scale"]
            self_slices["v_scale"] = cache["v_scale"]
        if cfg.family == "hybrid":
            self_slices["mamba_conv"] = cache["mamba_conv"]
            self_slices["mamba_ssm"] = cache["mamba_ssm"]
        if cfg.cross_attn_every == 1 and "cross_k" in cache:
            self_slices["cross_k"] = cache["cross_k"]
            self_slices["cross_v"] = cache["cross_v"]

        if not n_cross:
            x, new = layer_scan(self_step, x, (params["blocks"], self_slices), unroll=cfg.unroll_layers)
        else:
            group = cfg.cross_attn_every
            n_self_per = group - 1

            def group_step(x, xs):
                gp, gs, cp = xs
                x, new = layer_scan(self_step, x, (gp, gs), unroll=cfg.unroll_layers)
                h = rms_norm(x, cp["ln1"], cfg.norm_eps)
                if source is not None:
                    ck, cv = kv_for(cp["cross"], source.astype(x.dtype),
                                    with_rope=False)
                    qc = linear(cp["cross"], "wq", h).reshape(
                        b, sp, cfg.n_heads, dh)
                    c_out = attn_lib.prefill_attention(
                        qc, ck, cv, causal=False, kv_lengths=source_len,
                        kv_block=cfg.attn_block or 512)
                    c_out = linear(cp["cross"], "wo", c_out.reshape(b, sp, -1))
                    x = x + jnp.tanh(cp["cross"]["gate"]).astype(x.dtype) * c_out
                h2 = rms_norm(x, cp["ln2"], cfg.norm_eps)
                x = x + mlp_apply(cp["ffn"], h2, cfg.act, cfg.gated_mlp)
                if source is not None:
                    new["cross_k"] = ck.astype(cache["cross_k"].dtype)
                    new["cross_v"] = cv.astype(cache["cross_v"].dtype)
                return x, new

            gp = jax.tree.map(
                lambda a: a.reshape(n_cross, n_self_per, *a.shape[1:]),
                params["blocks"])
            gs = jax.tree.map(
                lambda a: a.reshape(n_cross, n_self_per, *a.shape[1:]),
                self_slices)
            x, new = layer_scan(group_step, x, (gp, gs, params["cross_blocks"]), unroll=cfg.unroll_layers)
            if source is not None:
                cross_new = {"cross_k": new.pop("cross_k"),
                             "cross_v": new.pop("cross_v")}
            new = jax.tree.map(
                lambda a: a.reshape(n_cross * n_self_per, *a.shape[2:]), new)
            if source is not None:
                new.update(cross_new)

        cache = dict(cache)
        for key, val in new.items():
            cache[key] = val
        if source is not None and "source_len" in cache:
            cache["source_len"] = (
                jnp.asarray(source_len, jnp.int32) if source_len is not None
                else jnp.full_like(cache["source_len"], source.shape[1]))
        cache["len"] = jnp.full_like(cache["len"], sp)
        if cfg.rotary_dim and cfg.rope_mode == "incremental":
            rs = rope_lib.rope_state_init(cfg.resolved_head_dim, cfg.rope_base,
                                          sp, cfg.rotary_dim)
            cache["rope_cos"] = jnp.broadcast_to(rs.cos_m, cache["rope_cos"].shape)
            cache["rope_sin"] = jnp.broadcast_to(rs.sin_m, cache["rope_sin"].shape)
        x = rms_norm(x[:, -1, :], params["ln_f"], cfg.norm_eps)
        return self._unembed(params, x), cache

    # ---- slot-targeted ragged prefill (continuous batching) ----------------
    def supports_ragged_serving(self) -> bool:
        """Every family serves ragged — the gated set is empty
        (``tests/test_serving_conformance.py`` pins that).

        Chunked slot prefill + masked ragged decode cover the dense-KV
        families; the recurrent-state families (ssm / hybrid) thread
        per-slot state in ``prefill_chunk`` and mask ``jnp.where`` state
        carries in ``decode_step``. The continuous MoE path is *drop-free
        by construction* (per-row dispatch at decode, capacity=C dispatch
        in chunk prefill), so a request's tokens never depend on batch
        composition; greedy equivalence against the lock-step engine is
        exact whenever the lock-step capacity-factor prefill itself drops
        nothing — under routing imbalance at low ``capacity_factor`` the
        *reference* drops tokens and the drop-free continuous output is the
        more faithful one.

        Ring KV caches (``kv_ring`` SWA configs) serve ragged too: parked
        rows use a per-slot write mask instead of the reserved tail row,
        chunked prefill writes at ``pos % ring_len`` with wrap, and the
        decode paths consume the ring in place (no unrotate copy).

        Cross-attention stacks (vlm / audio) — the last family to join —
        serve through the **source-KV pool**: encoder-side K/V is ingested
        once at admission into a refcounted pool entry keyed by source id
        (``init_cache(n_sources=...)`` + :meth:`ingest_source`), each
        slot's ``src_index`` points at its entry, and the decode /
        chunk-prefill cross reads mask per-slot source lengths so rows
        with different encoder lengths coexist in one static-shape
        dispatch (``attn_lib.decode_cross_attention``)."""
        return True

    def prefill_chunk(self, params: Params, tokens: jax.Array, cache: Cache,
                      slot: jax.Array, offset: jax.Array, last: jax.Array
                      ) -> tuple[jax.Array, Cache]:
        """Prefill one prompt chunk into a single cache slot at its own
        offset: tokens [C] run at absolute positions [offset, offset+C),
        K/V land in rows ``cache[k|v][:, slot, offset:offset+C]``, and the
        chunk attends causally to the slot's already-written prefix via
        ``prefill_attention``'s ``kv_lengths`` / ``q_offset`` raggedness.

        Chunking long prompts keeps each call small so in-flight decodes
        interleave instead of stalling behind a monolithic prefill. The
        caller pads the final chunk: padded positions write dead KV past the
        committed length (never attended — decode overwrites them).
        ``cache['len']`` is untouched until ``finalize_slot`` commits the
        full prompt length, so concurrent decode steps treat the slot as
        inactive throughout.

        Only chunk position ``last`` is unembedded (the caller needs one
        row of logits, on the final chunk — anything else would burn a
        [C, V] projection per chunk). Returns (logits [V] f32, cache).

        Recurrent families thread per-slot state: the ssm (RWKV) stack has
        no KV at all and runs :meth:`_rwkv_prefill_chunk`; hybrid layers
        continue the slot's (conv, ssm) Mamba state chunk to chunk, with
        padded tail positions masked into exact state no-ops. MoE FFNs use
        the capacity-free per-row dispatch (a padded position must not steal
        expert capacity from a real token).

        Ring KV configs (``kv_ring`` SWA) fill the slot's ring chunk by
        chunk at ``pos % ring_len`` — a prompt longer than the ring wraps
        and overwrites its own oldest (out-of-window) entries, which is
        what makes the long-context scenario (prompt >> window) servable at
        all. Padded tail positions are *keep*-masked (they rewrite the old
        slot value), so only real tokens ever occupy ring slots, and the
        chunk attends through :func:`attn_lib.prefill_attention_ring` —
        exact as long as ``ring_len >= window + chunk - 1`` (a later
        in-chunk token then only ever overwrites positions already outside
        every live query's window; the serving engine enforces the bound at
        construction — and ``init_cache(chunk=...)`` sizes the ring so it
        holds by construction).

        Cross-attention configs (vlm / audio) read the slot's **source-KV
        pool** entry: the chunk's queries cross-attend (non-causal, masked
        to the entry's ``src_len``) to ``src_k/src_v[:, src_index[slot]]``
        — already ingested at admission, never written here. A slot whose
        entry has ``src_len == 0`` (no source) gets an exact-zero cross
        term; a dedicated (vlm-style) cross layer still applies its FFN."""
        cfg = self.cfg
        if cfg.family == "ssm":
            return self._rwkv_prefill_chunk(params, tokens, cache, slot, last)
        (c,) = tokens.shape
        dh = cfg.resolved_head_dim
        smax, hkv = cache["k"].shape[2], cfg.n_kv_heads
        x = params["embed"].astype(self._dt)[tokens][None]       # [1, C, d]
        positions = offset + jnp.arange(c)
        kv_len = jnp.reshape(offset + c, (1,)).astype(jnp.int32)
        q_off = jnp.reshape(offset, (1,)).astype(jnp.int32)
        n_valid = last + 1

        ring = bool(cfg.kv_ring and cfg.window)
        pooled_src = "src_k" in cache
        if pooled_src:
            s_src = cache["src_k"].shape[2]
            entry = jnp.take(cache["src_index"], slot)
            src_n = jnp.reshape(jnp.take(cache["src_len"], entry),
                                (1,)).astype(jnp.int32)

        def cross_read(cp, hc, sk_all, sv_all, sks_all=None, svs_all=None):
            """Chunk queries (pre-normed ``hc`` [1, C, d]) against this
            slot's pool entry in one layer's source KV ([E, S_src, Hkv,
            Dh]) — read-only, masked to the entry's valid prefix. An int8
            pool (``sks_all/svs_all`` [E, Hkv, S_src] scales) dequantizes
            just this entry's slice — one [S_src, Hkv, Dh] f32
            materialization per layer per chunk, not the whole pool."""
            qc = linear(cp, "wq", hc).reshape(1, c, cfg.n_heads, dh)
            if cfg.qk_norm:
                qc = rms_norm(qc, cp["qn"], cfg.norm_eps)
            sk = jax.lax.dynamic_slice(sk_all, (entry, 0, 0, 0),
                                       (1, s_src, hkv, dh))
            sv = jax.lax.dynamic_slice(sv_all, (entry, 0, 0, 0),
                                       (1, s_src, hkv, dh))
            if sks_all is not None:
                sks = jax.lax.dynamic_slice(sks_all, (entry, 0, 0),
                                            (1, hkv, s_src))
                svs = jax.lax.dynamic_slice(svs_all, (entry, 0, 0),
                                            (1, hkv, s_src))
                sk = sk.astype(jnp.float32) * jnp.swapaxes(sks, 1, 2)[..., None]
                sv = sv.astype(jnp.float32) * jnp.swapaxes(svs, 1, 2)[..., None]
            out = attn_lib.prefill_attention(qc, sk, sv, causal=False,
                                             kv_lengths=src_n,
                                             kv_block=cfg.attn_block or 512)
            out = linear(cp, "wo", out.reshape(1, c, -1))
            return jnp.tanh(cp["gate"]).astype(hc.dtype) * out

        def step(x, xs):
            bp, slices = xs
            new = {}
            ap = bp["attn"]
            h = rms_norm(x, bp["ln1"], cfg.norm_eps)
            q, k, v = self._qkv_rope(ap, h, positions)
            quant = "k_scale" in slices
            if quant:
                # int8 cache: chunk K/V quantize per (position, head); the
                # chunk then attends *through the cache slot* (unlike full
                # prefill), so the slot reads below dequantize whole-row.
                # The current chunk's own positions are overlaid with their
                # fresh fp values — quantization noise enters a chunk's
                # attention only through the *already-written* prefix, the
                # part that is genuinely stored int8 at read time. This is
                # what keeps single-chunk prompts bit-identical to the
                # lock-step quantized prefill (which attends fp K/V
                # throughout) and the measured agreement tier tight.
                k_fp, v_fp = k, v
                k, k_s = quantize_kv(k)                  # k_s [1, C, Hkv]
                v, v_s = quantize_kv(v)
                k_s = k_s.astype(slices["k_scale"].dtype)
                v_s = v_s.astype(slices["v_scale"].dtype)
            if ring:
                # ring fill: chunk token at absolute position p lands in
                # ring slot p % R (wrap-aware scatter); padded tail rows
                # (> last) keep the old slot value so only real tokens
                # occupy ring slots
                idx = jnp.mod(positions, smax)                   # [C]
                keep = (jnp.arange(c) <= last)[:, None, None]
                k_slot = jax.lax.dynamic_slice(slices["k"], (slot, 0, 0, 0),
                                               (1, smax, hkv, dh))
                v_slot = jax.lax.dynamic_slice(slices["v"], (slot, 0, 0, 0),
                                               (1, smax, hkv, dh))
                k_slot = k_slot.at[0, idx].set(
                    jnp.where(keep, k[0].astype(k_slot.dtype), k_slot[0, idx]))
                v_slot = v_slot.at[0, idx].set(
                    jnp.where(keep, v[0].astype(v_slot.dtype), v_slot[0, idx]))
                kc = jax.lax.dynamic_update_slice(slices["k"], k_slot,
                                                  (slot, 0, 0, 0))
                vc = jax.lax.dynamic_update_slice(slices["v"], v_slot,
                                                  (slot, 0, 0, 0))
                k_att, v_att = k_slot, v_slot
                if quant:
                    # same keep-masked ring scatter on the scale planes,
                    # position-major for the gather then back to [1, Hkv, R]
                    keep_s = (jnp.arange(c) <= last)[:, None]
                    ks_t = jnp.swapaxes(jax.lax.dynamic_slice(
                        slices["k_scale"], (slot, 0, 0),
                        (1, hkv, smax))[0], 0, 1)        # [R, Hkv]
                    vs_t = jnp.swapaxes(jax.lax.dynamic_slice(
                        slices["v_scale"], (slot, 0, 0),
                        (1, hkv, smax))[0], 0, 1)
                    ks_t = ks_t.at[idx].set(
                        jnp.where(keep_s, k_s[0], ks_t[idx]))
                    vs_t = vs_t.at[idx].set(
                        jnp.where(keep_s, v_s[0], vs_t[idx]))
                    new["k_scale"] = jax.lax.dynamic_update_slice(
                        slices["k_scale"], jnp.swapaxes(ks_t, 0, 1)[None],
                        (slot, 0, 0))
                    new["v_scale"] = jax.lax.dynamic_update_slice(
                        slices["v_scale"], jnp.swapaxes(vs_t, 0, 1)[None],
                        (slot, 0, 0))
                    k_att = k_slot.astype(jnp.float32) * ks_t[None, :, :, None]
                    v_att = v_slot.astype(jnp.float32) * vs_t[None, :, :, None]
                    # fresh-fp overlay of the current chunk's ring slots
                    k_att = k_att.at[0, idx].set(
                        jnp.where(keep, k_fp[0].astype(jnp.float32),
                                  k_att[0, idx]))
                    v_att = v_att.at[0, idx].set(
                        jnp.where(keep, v_fp[0].astype(jnp.float32),
                                  v_att[0, idx]))
                attn = attn_lib.prefill_attention_ring(
                    q, k_att, v_att, positions, offset + last,
                    window=cfg.window)
            else:
                kc = jax.lax.dynamic_update_slice(
                    slices["k"], k.astype(slices["k"].dtype),
                    (slot, offset, 0, 0))
                vc = jax.lax.dynamic_update_slice(
                    slices["v"], v.astype(slices["v"].dtype),
                    (slot, offset, 0, 0))
                k_slot = jax.lax.dynamic_slice(kc, (slot, 0, 0, 0),
                                               (1, smax, hkv, dh))
                v_slot = jax.lax.dynamic_slice(vc, (slot, 0, 0, 0),
                                               (1, smax, hkv, dh))
                if quant:
                    new["k_scale"] = jax.lax.dynamic_update_slice(
                        slices["k_scale"], jnp.swapaxes(k_s, 1, 2),
                        (slot, 0, offset))
                    new["v_scale"] = jax.lax.dynamic_update_slice(
                        slices["v_scale"], jnp.swapaxes(v_s, 1, 2),
                        (slot, 0, offset))
                    ks_slot = jax.lax.dynamic_slice(
                        new["k_scale"], (slot, 0, 0), (1, hkv, smax))
                    vs_slot = jax.lax.dynamic_slice(
                        new["v_scale"], (slot, 0, 0), (1, hkv, smax))
                    k_slot = (k_slot.astype(jnp.float32)
                              * jnp.swapaxes(ks_slot, 1, 2)[..., None])
                    v_slot = (v_slot.astype(jnp.float32)
                              * jnp.swapaxes(vs_slot, 1, 2)[..., None])
                    # fresh-fp overlay of the current chunk's positions
                    k_slot = jax.lax.dynamic_update_slice(
                        k_slot, k_fp.astype(jnp.float32), (0, offset, 0, 0))
                    v_slot = jax.lax.dynamic_update_slice(
                        v_slot, v_fp.astype(jnp.float32), (0, offset, 0, 0))
                attn = attn_lib.prefill_attention(
                    q, k_slot, v_slot, causal=True, window=cfg.window,
                    kv_lengths=kv_len, q_offset=q_off,
                    kv_block=cfg.attn_block or 512)
            attn_out = linear(ap, "wo", attn.reshape(1, c, -1))
            new["k"], new["v"] = kc, vc
            if cfg.family == "hybrid":
                d_inner = cfg.ssm_expand * cfg.d_model
                conv0 = jax.lax.dynamic_slice(
                    slices["mamba_conv"], (slot, 0, 0),
                    (1, cfg.ssm_conv - 1, d_inner))
                ssm0 = jax.lax.dynamic_slice(
                    slices["mamba_ssm"], (slot, 0, 0),
                    (1, d_inner, cfg.ssm_state))
                m_out, mst = mamba_lib.mamba_forward(
                    bp["mamba"], h, return_state=True,
                    state=mamba_lib.MambaState(conv=conv0, ssm=ssm0),
                    n_valid=n_valid)
                new["mamba_conv"] = jax.lax.dynamic_update_slice(
                    slices["mamba_conv"], mst.conv, (slot, 0, 0))
                new["mamba_ssm"] = jax.lax.dynamic_update_slice(
                    slices["mamba_ssm"], mst.ssm, (slot, 0, 0))
                x = x + 0.5 * (rms_norm(attn_out, bp["ln_attn_out"],
                                        cfg.norm_eps)
                               + rms_norm(m_out, bp["ln_mamba_out"],
                                          cfg.norm_eps))
            else:
                x = x + attn_out
            if "cross" in bp and "src_k" in slices:   # whisper-style in-layer
                hc = rms_norm(x, bp["ln_cross"], cfg.norm_eps)
                x = x + cross_read(bp["cross"], hc, slices["src_k"],
                                   slices["src_v"],
                                   slices.get("src_k_scale"),
                                   slices.get("src_v_scale"))
            h2 = rms_norm(x, bp["ln2"], cfg.norm_eps)
            if cfg.n_experts:
                # capacity = chunk length C: each token assigns an expert at
                # most once, so per-expert load <= C and nothing can drop —
                # drop-free capacity dispatch equals the per-row form
                # exactly, padded positions can't evict real tokens, and the
                # [E, C, d] queue stays small (the per-row dense gather
                # would materialize C*k full expert matrices per layer)
                y, _ = moe_lib.moe_apply(bp["ffn"], h2, top_k=cfg.top_k,
                                         act=cfg.act, gated=cfg.gated_mlp,
                                         capacity=c)
            else:
                y = mlp_apply(bp["ffn"], h2, cfg.act, cfg.gated_mlp)
            return x + y, new

        self_slices = {"k": cache["k"], "v": cache["v"]}
        if "k_scale" in cache:
            self_slices["k_scale"] = cache["k_scale"]
            self_slices["v_scale"] = cache["v_scale"]
        if cfg.family == "hybrid":
            self_slices["mamba_conv"] = cache["mamba_conv"]
            self_slices["mamba_ssm"] = cache["mamba_ssm"]
        if cfg.cross_attn_every == 1 and pooled_src:   # whisper-style
            self_slices["src_k"] = cache["src_k"]
            self_slices["src_v"] = cache["src_v"]
            if "src_k_scale" in cache:
                self_slices["src_k_scale"] = cache["src_k_scale"]
                self_slices["src_v_scale"] = cache["src_v_scale"]

        n_cross = self._n_cross_groups()
        if not n_cross:
            x, new = layer_scan(step, x, (params["blocks"], self_slices),
                                unroll=cfg.unroll_layers)
        else:                                          # vlm: dedicated cross
            group = cfg.cross_attn_every
            n_self_per = group - 1
            cross_xs = ((cache["src_k"], cache["src_v"]) if pooled_src
                        else ())
            if pooled_src and "src_k_scale" in cache:
                cross_xs += (cache["src_k_scale"], cache["src_v_scale"])

            def group_step(x, xs):
                gp, gs, cp, *skv = xs
                x, new = layer_scan(step, x, (gp, gs),
                                    unroll=cfg.unroll_layers)
                if pooled_src:
                    hc = rms_norm(x, cp["ln1"], cfg.norm_eps)
                    x = x + cross_read(cp["cross"], hc, skv[0], skv[1],
                                       *(skv[2:4] if len(skv) > 2
                                         else (None, None)))
                h2 = rms_norm(x, cp["ln2"], cfg.norm_eps)
                x = x + mlp_apply(cp["ffn"], h2, cfg.act, cfg.gated_mlp)
                return x, new

            gp = jax.tree.map(
                lambda a: a.reshape(n_cross, n_self_per, *a.shape[1:]),
                params["blocks"])
            gs = jax.tree.map(
                lambda a: a.reshape(n_cross, n_self_per, *a.shape[1:]),
                self_slices)
            x, new = layer_scan(group_step, x,
                                (gp, gs, params["cross_blocks"], *cross_xs),
                                unroll=cfg.unroll_layers)
            new = jax.tree.map(
                lambda a: a.reshape(n_cross * n_self_per, *a.shape[2:]), new)
        cache = dict(cache)
        for key, val in new.items():
            cache[key] = val
        x_last = jax.lax.dynamic_slice(x, (0, last, 0),
                                       (1, 1, cfg.d_model))[:, 0]
        x_last = rms_norm(x_last, params["ln_f"], cfg.norm_eps)
        return self._unembed(params, x_last)[0], cache

    def _rwkv_prefill_chunk(self, params: Params, tokens: jax.Array,
                            cache: Cache, slot: jax.Array, last: jax.Array
                            ) -> tuple[jax.Array, Cache]:
        """One prompt chunk through the RWKV stack for a single slot: the
        slot's per-layer (x_prev, wkv) state seeds the chunk scan and the
        post-chunk state is written back, so successive chunks compose into
        exactly the full-prompt recurrence. Positions past ``last`` are
        padding — masked into state no-ops inside the mix kernels. The slot
        has no KV rows; ``offset`` is implicit in the carried state."""
        cfg = self.cfg
        x = params["embed"].astype(self._dt)[tokens][None]       # [1, C, d]
        n_valid = last + 1
        att0 = jax.lax.dynamic_slice_in_dim(cache["rwkv_att"], slot, 1, axis=1)
        ffn0 = jax.lax.dynamic_slice_in_dim(cache["rwkv_ffn"], slot, 1, axis=1)
        wkv0 = jax.lax.dynamic_slice_in_dim(cache["rwkv_wkv"], slot, 1, axis=1)

        def step(x, xs):
            bp, att_prev, ffn_prev, wkv = xs                     # [1, ...]
            st = rwkv_lib.RWKVLayerState(att_prev.astype(self._dt),
                                         ffn_prev.astype(self._dt), wkv)
            h = rms_norm(x, bp["ln1"], cfg.norm_eps)
            y, st = rwkv_lib.rwkv_time_mix(bp["mix"], h, st,
                                           cfg.rwkv_head_dim, n_valid=n_valid)
            x = x + y
            h2 = rms_norm(x, bp["ln2"], cfg.norm_eps)
            y2, st = rwkv_lib.rwkv_channel_mix(bp["mix"], h2, st,
                                               n_valid=n_valid)
            return x + y2, (st.x_prev_att.astype(att_prev.dtype),
                            st.x_prev_ffn.astype(ffn_prev.dtype), st.wkv)

        x, (att, ffn, wkv) = layer_scan(step, x,
                                        (params["blocks"], att0, ffn0, wkv0),
                                        unroll=cfg.unroll_layers)
        cache = dict(
            cache,
            rwkv_att=jax.lax.dynamic_update_slice_in_dim(
                cache["rwkv_att"], att, slot, axis=1),
            rwkv_ffn=jax.lax.dynamic_update_slice_in_dim(
                cache["rwkv_ffn"], ffn, slot, axis=1),
            rwkv_wkv=jax.lax.dynamic_update_slice_in_dim(
                cache["rwkv_wkv"], wkv, slot, axis=1))
        x_last = jax.lax.dynamic_slice(x, (0, last, 0),
                                       (1, 1, cfg.d_model))[:, 0]
        x_last = rms_norm(x_last, params["ln_f"], cfg.norm_eps)
        return self._unembed(params, x_last)[0], cache

    def prefill_chunks_batched(self, params: Params, tokens: jax.Array,
                               cache: Cache, slots: jax.Array,
                               offsets: jax.Array, lasts: jax.Array,
                               valid: jax.Array) -> tuple[jax.Array, Cache]:
        """Advance N mid-prefill slots one prompt chunk each in a *single*
        dispatch: a ``lax.scan`` over rows, each applying the
        :meth:`prefill_chunk` body for its own (slot, offset). Slots write
        disjoint cache rows / state entries, so the sequential in-program
        application is exactly equivalent to N separate ``prefill_chunk``
        calls — it just costs one host round-trip instead of N (the
        continuous engine's per-step prefill loop was one dispatch *per
        slot* before this). Rows with ``valid=False`` are skipped via
        ``lax.cond`` (zero logits, cache untouched), so the program
        compiles once at a fixed N = n_slots regardless of how many slots
        are mid-prefill.

        tokens: [N, C] int32; slots/offsets/lasts: [N] int32; valid: [N]
        bool. Returns (logits [N, V] f32 — row i meaningful only on request
        i's final chunk, matching prefill_chunk's contract — and the
        updated cache)."""
        vocab = self.cfg.vocab_size

        def row(cache, xs):
            toks, slot, off, last, ok = xs

            def run(c):
                return self.prefill_chunk(params, toks, c, slot, off, last)

            def skip(c):
                return jnp.zeros((vocab,), jnp.float32), c

            logits, cache = jax.lax.cond(ok, run, skip, cache)
            return cache, logits

        cache, logits = jax.lax.scan(
            row, cache, (tokens, slots, offsets, lasts, valid))
        return logits, cache

    # ---- source-KV pool (cross-attention continuous serving) ---------------
    def ingest_source(self, params: Params, source: jax.Array, cache: Cache,
                      entry: jax.Array, length: jax.Array) -> Cache:
        """Write one source's encoder-side cross K/V into pool entry
        ``entry`` — the write-once half of the source-KV pool contract
        (computed at admission, read-only for every decode tick after).

        source: [S_max, d] frontend features, padded to the pool row size;
        ``length``: the valid prefix. Each cross layer's ``wk``/``wv``
        projects the source once (no RoPE — cross keys are position-free,
        matching :meth:`prefill`'s ``with_rope=False``); rows past
        ``length`` are zeroed so a pool entry's device state is exactly
        (real K/V, zeros) — never a previous occupant's tail — and every
        read additionally masks by ``src_len``. The caller
        (``repro.serving.continuous``) owns the host-side ledger
        (``SourceKVPool``): which entry a source id maps to, refcounts, and
        when :meth:`release_source` may zero the entry."""
        cfg = self.cfg
        dh = cfg.resolved_head_dim
        src = source.astype(self._dt)                        # [S_max, d]
        stacked = (params["cross_blocks"] if cfg.cross_attn_every > 1
                   else params["blocks"])

        def proj(bp):
            p = bp["cross"]
            k = linear(p, "wk", src).reshape(-1, cfg.n_kv_heads, dh)
            v = linear(p, "wv", src).reshape(-1, cfg.n_kv_heads, dh)
            if cfg.qk_norm:
                k = rms_norm(k, p["kn"], cfg.norm_eps)
            return k, v

        ks, vs = jax.vmap(proj)(stacked)                     # [Lc, S, Hkv, Dh]
        keep = (jnp.arange(ks.shape[1]) < length)[None, :, None, None]
        ks = jnp.where(keep, ks, 0)
        vs = jnp.where(keep, vs, 0)
        cache = dict(cache)
        if "src_k_scale" in cache:
            # int8 pool: quantize after the tail zeroing so padded rows get
            # (0, scale 0) — the entry's device state stays inspectably zero
            ks, k_s = quantize_kv(ks)                    # k_s [Lc, S, Hkv]
            vs, v_s = quantize_kv(vs)
            cache["src_k_scale"] = jax.lax.dynamic_update_slice(
                cache["src_k_scale"],
                jnp.swapaxes(k_s, 1, 2)[:, None].astype(
                    cache["src_k_scale"].dtype),
                (0, entry, 0, 0))
            cache["src_v_scale"] = jax.lax.dynamic_update_slice(
                cache["src_v_scale"],
                jnp.swapaxes(v_s, 1, 2)[:, None].astype(
                    cache["src_v_scale"].dtype),
                (0, entry, 0, 0))
        ks = ks.astype(cache["src_k"].dtype)
        vs = vs.astype(cache["src_v"].dtype)
        cache["src_k"] = jax.lax.dynamic_update_slice(
            cache["src_k"], ks[:, None], (0, entry, 0, 0, 0))
        cache["src_v"] = jax.lax.dynamic_update_slice(
            cache["src_v"], vs[:, None], (0, entry, 0, 0, 0))
        cache["src_len"] = cache["src_len"].at[entry].set(
            jnp.asarray(length, jnp.int32))
        return cache

    def assign_source(self, cache: Cache, slot: jax.Array,
                      entry: jax.Array) -> Cache:
        """Point a slot's cross-attention reads at pool entry ``entry``
        (``src_index[slot] = entry``). Sharing is this one int: any number
        of slots may map to the same entry."""
        return dict(cache, src_index=cache["src_index"].at[slot].set(
            jnp.asarray(entry, jnp.int32)))

    def release_source(self, cache: Cache, entry: jax.Array) -> Cache:
        """Zero a pool entry's source K/V rows and its ``src_len`` — called
        only when the entry's last reference retired (the ``SourceKVPool``
        ledger decides). After this, any slot still pointing at the entry
        (an inactive slot whose output is discarded anyway) reads a
        fully-masked zero; a backfilled request can never see the previous
        occupant's encoder state."""
        cache = dict(cache)
        cache["src_k"] = cache["src_k"].at[:, entry].set(0)
        cache["src_v"] = cache["src_v"].at[:, entry].set(0)
        cache["src_len"] = cache["src_len"].at[entry].set(0)
        for key in ("src_k_scale", "src_v_scale"):
            if key in cache:
                cache[key] = cache[key].at[:, entry].set(0)
        return cache

    def finalize_slot(self, cache: Cache, slot: jax.Array,
                      length: jax.Array) -> Cache:
        """Commit a slot's chunked prefill: set its live length and reseed
        its incremental-RoPE angle state at position ``length`` (direct mode
        recomputes from ``len`` and needs no per-slot state). Everything in
        the slot past ``length`` is dead until decode overwrites it."""
        cfg = self.cfg
        length = jnp.asarray(length, jnp.int32)
        cache = dict(cache, len=cache["len"].at[slot].set(length))
        if cfg.rotary_dim and cfg.rope_mode == "incremental":
            rs = rope_lib.rope_state_init(cfg.resolved_head_dim,
                                          cfg.rope_base, length,
                                          cfg.rotary_dim)
            cache["rope_cos"] = cache["rope_cos"].at[slot].set(rs.cos_m)
            cache["rope_sin"] = cache["rope_sin"].at[slot].set(rs.sin_m)
        return cache

    def release_slot(self, cache: Cache, slot: jax.Array) -> Cache:
        """Reset-on-release: drop the slot's length to zero so nothing in
        its KV rows is attended again; the next occupant's prefill
        overwrites the contents in place. Recurrent state (RWKV x_prev/wkv,
        Mamba conv/ssm) is *zeroed*, not just ignored — unlike KV rows it
        feeds forward multiplicatively, so the next occupant's first chunk
        must start from the empty-context state. Ring KV rows are zeroed
        too: the ring position-recovery formula already masks a previous
        occupant's stale slots (their recovered position is negative until
        the new request wraps), but zeroing keeps the reset contract
        uniform and inspectable — after release a slot's device state is
        all-zeros for every family."""
        cache = dict(cache, len=cache["len"].at[slot].set(0))
        for key in ("rwkv_att", "rwkv_ffn", "rwkv_wkv",
                    "mamba_conv", "mamba_ssm"):
            if key in cache:
                cache[key] = cache[key].at[:, slot].set(0)
        if (self.cfg.kv_ring and self.cfg.window) or "k_scale" in cache:
            # ring caches zero for the uniform-reset contract; int8 caches
            # additionally zero so a released slot's (rows, scales) pair is
            # all-zeros — scale 0 means a stale row can never dequantize to
            # a previous occupant's value even if misread
            for key in ("k", "v", "k_scale", "v_scale"):
                if key in cache:
                    cache[key] = cache[key].at[:, slot].set(0)
        return cache

    def _rwkv_prefill(self, params: Params, x: jax.Array,
                      cache: Cache) -> tuple[jax.Array, Cache]:
        cfg = self.cfg
        b, sp, _ = x.shape
        h_heads = cfg.d_model // cfg.rwkv_head_dim

        def step(x, bp):
            st0 = rwkv_lib.RWKVLayerState(
                x_prev_att=jnp.zeros((b, cfg.d_model), x.dtype),
                x_prev_ffn=jnp.zeros((b, cfg.d_model), x.dtype),
                wkv=jnp.zeros((b, h_heads, cfg.rwkv_head_dim,
                               cfg.rwkv_head_dim), jnp.float32))
            h = rms_norm(x, bp["ln1"], cfg.norm_eps)
            y, st = rwkv_lib.rwkv_time_mix(bp["mix"], h, st0, cfg.rwkv_head_dim)
            x = x + y
            h2 = rms_norm(x, bp["ln2"], cfg.norm_eps)
            y2, st = rwkv_lib.rwkv_channel_mix(bp["mix"], h2, st)
            return x + y2, (st.x_prev_att, st.x_prev_ffn, st.wkv)

        x, (att, ffn, wkv) = layer_scan(step, x, params["blocks"], unroll=cfg.unroll_layers)
        cache = dict(cache, rwkv_att=att, rwkv_ffn=ffn, rwkv_wkv=wkv,
                     len=jnp.full_like(cache["len"], sp))
        x = rms_norm(x[:, -1, :], params["ln_f"], cfg.norm_eps)
        return self._unembed(params, x), cache

    def _rwkv_decode_step(self, params: Params, x: jax.Array, cache: Cache,
                          active: jax.Array | None = None
                          ) -> tuple[jax.Array, Cache]:
        cfg = self.cfg

        def step(x, xs):
            bp, att_prev, ffn_prev, wkv = xs
            st = rwkv_lib.RWKVLayerState(att_prev.astype(self._dt),
                                         ffn_prev.astype(self._dt), wkv)
            h = rms_norm(x, bp["ln1"], cfg.norm_eps)
            # ragged batch: inactive rows are exact state no-ops — masked at
            # the state-update site in rwkv6.py
            y, st = rwkv_lib.rwkv_time_mix_step(bp["mix"], h, st,
                                                cfg.rwkv_head_dim,
                                                active=active)
            x = x + y
            h2 = rms_norm(x, bp["ln2"], cfg.norm_eps)
            y2, st = rwkv_lib.rwkv_channel_mix_step(bp["mix"], h2, st,
                                                    active=active)
            return x + y2, (st.x_prev_att, st.x_prev_ffn, st.wkv)

        x, (att, ffn, wkv) = layer_scan(
            step, x, (params["blocks"], cache["rwkv_att"], cache["rwkv_ffn"],
                      cache["rwkv_wkv"]), unroll=cfg.unroll_layers)
        cache = dict(cache, rwkv_att=att, rwkv_ffn=ffn, rwkv_wkv=wkv,
                     len=cache["len"] + (1 if active is None
                                         else active.astype(jnp.int32)))
        x = rms_norm(x, params["ln_f"], cfg.norm_eps)
        return self._unembed(params, x), cache
