"""Model & shape configuration. One ``ModelConfig`` describes every assigned
architecture family (dense / moe / ssm / hybrid / vlm / audio)."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | vlm | audio
    vocab_size: int
    d_model: int
    n_layers: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    head_dim: int | None = None       # default: d_model // n_heads
    act: str = "silu"                 # silu | gelu
    gated_mlp: bool = True            # SwiGLU / GeGLU vs plain MLP
    qk_norm: bool = False             # qwen3-style per-head RMSNorm on q,k
    rope_base: float = 10000.0
    rotary_frac: float = 1.0          # fraction of head_dim rotated
    window: int | None = None         # sliding-window attention
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # --- SSM (mamba branch of hybrid archs) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    # --- RWKV6 ---
    rwkv_head_dim: int = 64
    # --- cross-attention (vlm) / encoder-decoder (audio) ---
    cross_attn_every: int = 0         # every Nth decoder layer cross-attends
    encoder_layers: int = 0           # >0: encoder-decoder (whisper)
    source_len: int = 1500            # stub frontend sequence length
    # --- numerics / serving ---
    compute_dtype: str = "bfloat16"
    decode_impl: str = "blockwise"    # blockwise | tokenwise | kernel | naive
                                      # | sp (sequence-parallel monoid merge)
    rope_mode: str = "incremental"    # incremental (paper Eq.11) | direct
    remat_policy: str = "full"        # full | dots — dots saves matmul
                                      # outputs at layer boundaries (less
                                      # recompute, more live memory)
    w4a8_serve: bool = False          # serving: int4-packed projections +
                                      # int8 activations (paper §IV-B) — 4x
                                      # less weight traffic on decode
    kv_ring: bool = False             # SWA archs: ring KV cache of size
                                      # ~window instead of the full context
                                      # (beyond-paper; long_500k hillclimb)
    # --- lowering ---
    unroll_layers: bool = False       # dry-run: python-loop the layer stack so
                                      # cost_analysis counts every layer (scan
                                      # bodies are costed once by XLA)
    attn_block: int | None = None     # KV-block size for the single-pass
                                      # attention scans (default 512). The
                                      # dry-run cost pass sets it to seq_len
                                      # so the block loop disappears and XLA
                                      # costs the full attention work.
    # --- capability flags ---
    sub_quadratic: bool = False       # may run long_500k
    notes: str = ""

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def rotary_dim(self) -> int:
        rd = int(self.resolved_head_dim * self.rotary_frac)
        return rd - (rd % 2)

    @property
    def is_encoder_decoder(self) -> bool:
        return self.encoder_layers > 0

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str        # train | prefill | decode


SHAPES: dict[str, ShapeSpec] = {
    "train_4k":    ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k":  ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k":   ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(runs?, reason-if-not). long_500k needs a sub-quadratic path
    (SSM / SWA); pure full-attention archs skip it (DESIGN.md §4)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention arch: 500k decode needs a sub-quadratic path"
    return True, ""
