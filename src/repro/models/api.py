"""Uniform model API: build_model / input_specs / lm_loss.

``input_specs`` returns ShapeDtypeStruct stand-ins for every model input of an
(arch x shape) cell — weak-type-correct, shardable, zero allocation — used by
the multi-pod dry-run and the roofline harness.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .config import ModelConfig, ShapeSpec
from .transformer import TransformerLM
from .whisper import WhisperModel


def build_model(cfg: ModelConfig):
    if cfg.family == "audio":
        return WhisperModel(cfg)
    return TransformerLM(cfg)


def needs_source(cfg: ModelConfig) -> bool:
    return cfg.family in ("vlm", "audio")


def source_spec(cfg: ModelConfig, batch: int) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct((batch, cfg.source_len, cfg.d_model),
                                jnp.dtype(cfg.compute_dtype))


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct pytree for one (arch, shape) cell."""
    b, s = shape.global_batch, shape.seq_len
    model = build_model(cfg)
    i32 = jnp.int32
    if shape.kind == "train":
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), i32),
                 "labels": jax.ShapeDtypeStruct((b, s), i32)}
        if needs_source(cfg):
            specs["source"] = source_spec(cfg, b)
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        if needs_source(cfg):
            specs["source"] = source_spec(cfg, b)
        return specs
    # decode: one new token against a cache of length s
    src_len = cfg.source_len if needs_source(cfg) else None
    cache = jax.eval_shape(
        functools.partial(model.init_cache, b, s, src_len))
    return {"tokens": jax.ShapeDtypeStruct((b,), i32), "cache": cache}


def lm_loss(model, params, tokens: jax.Array, labels: jax.Array,
            source: jax.Array | None = None, *, aux_weight: float = 0.01,
            remat: bool = True) -> jax.Array:
    """Causal-LM cross entropy (+ MoE load-balance aux).

    The label pick is a masked sum rather than ``take_along_axis`` so the
    vocab axis can stay model-sharded end to end (a gather along a sharded
    axis forces GSPMD into a full-vocab re-layout; the mask-sum lowers to a
    partial sum + tiny all-reduce)."""
    kw = {"source": source} if source is not None else {}
    logits, aux = model.forward(params, tokens, remat=remat, **kw)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          logits.ndim - 1)
    picked = jnp.where(vocab_iota == labels[..., None], logits, 0.0)
    ll = jnp.sum(picked, axis=-1)
    return jnp.mean(logz - ll) + aux_weight * aux
